//! JSON-lines workload trace format: record synthetic runs, replay them
//! byte-identically, and import external traces into the simulator.
//!
//! Format: one JSON object per line. The first line is a header object
//! (`{"type":"header",...}`), subsequent lines are events. Two event kinds
//! exist — `arrival` carries the full workload spec, `departure` is
//! derivable from arrivals and optional (written for human inspection,
//! ignored on load).

use std::io::{BufRead, Write};
use std::path::Path;

use super::spec::Workload;
use crate::util::json::Json;

/// A trace event.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Arrival(Workload),
    /// (workload id, slot) — informational.
    Departure(u64, u64),
}

/// An in-memory workload trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Free-form description (distribution name, seed, generator version).
    pub description: String,
    /// Cluster capacity in slices the trace was generated against.
    pub capacity_slices: u64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(description: &str, capacity_slices: u64) -> Self {
        Self { description: description.to_string(), capacity_slices, events: Vec::new() }
    }

    /// Build a trace from an arrival sequence (departures synthesized).
    pub fn from_workloads(
        description: &str,
        capacity_slices: u64,
        workloads: &[Workload],
    ) -> Self {
        let mut t = Self::new(description, capacity_slices);
        for w in workloads {
            t.events.push(TraceEvent::Arrival(*w));
        }
        // Synthesize departures in slot order for readability.
        let mut departures: Vec<(u64, u64)> =
            workloads.iter().map(|w| (w.id.0, w.departure_slot())).collect();
        departures.sort_by_key(|&(_, slot)| slot);
        for (id, slot) in departures {
            t.events.push(TraceEvent::Departure(id, slot));
        }
        t
    }

    /// The arrival sequence in arrival-slot order.
    pub fn arrivals(&self) -> Vec<Workload> {
        let mut ws: Vec<Workload> = self
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Arrival(w) => Some(*w),
                TraceEvent::Departure(..) => None,
            })
            .collect();
        ws.sort_by_key(|w| (w.arrival_slot, w.id));
        ws
    }

    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        let header = Json::obj()
            .with("type", "header")
            .with("format", "migsched-trace-v1")
            .with("description", self.description.as_str())
            .with("capacity_slices", self.capacity_slices);
        out.push_str(&header.to_string_compact());
        out.push('\n');
        for e in &self.events {
            let j = match e {
                TraceEvent::Arrival(w) => {
                    let mut j = w.to_json();
                    j.set("type", "arrival");
                    j
                }
                TraceEvent::Departure(id, slot) => Json::obj()
                    .with("type", "departure")
                    .with("id", *id)
                    .with("slot", *slot),
            };
            out.push_str(&j.to_string_compact());
            out.push('\n');
        }
        out
    }

    pub fn parse_jsonl(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines.next().ok_or("empty trace")?;
        let header = Json::parse(header_line).map_err(|e| format!("header: {e}"))?;
        if header.req_str("type")? != "header" {
            return Err("first line must be the header object".into());
        }
        let format = header.req_str("format")?;
        if format != "migsched-trace-v1" {
            return Err(format!("unsupported trace format '{format}'"));
        }
        let mut trace = Trace::new(
            header.get("description").and_then(Json::as_str).unwrap_or(""),
            header.req_u64("capacity_slices")?,
        );
        for (lineno, line) in lines.enumerate() {
            let j = Json::parse(line).map_err(|e| format!("line {}: {e}", lineno + 2))?;
            match j.req_str("type")? {
                "arrival" => trace.events.push(TraceEvent::Arrival(Workload::from_json(&j)?)),
                "departure" => trace
                    .events
                    .push(TraceEvent::Departure(j.req_u64("id")?, j.req_u64("slot")?)),
                other => return Err(format!("line {}: unknown event '{other}'", lineno + 2)),
            }
        }
        Ok(trace)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render_jsonl().as_bytes())
    }

    pub fn load(path: &Path) -> Result<Trace, String> {
        let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut text = String::new();
        let mut reader = std::io::BufReader::new(f);
        loop {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Ok(_) => text.push_str(&line),
                Err(e) => return Err(format!("{}: {e}", path.display())),
            }
        }
        Self::parse_jsonl(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;
    use crate::workload::spec::{TenantId, WorkloadId};

    fn sample_workloads() -> Vec<Workload> {
        vec![
            Workload {
                id: WorkloadId(0),
                tenant: TenantId(0),
                profile: Profile::P2g20gb,
                arrival_slot: 0,
                duration_slots: 3,
            },
            Workload {
                id: WorkloadId(1),
                tenant: TenantId(1),
                profile: Profile::P7g80gb,
                arrival_slot: 1,
                duration_slots: 1,
            },
        ]
    }

    #[test]
    fn roundtrip() {
        let t = Trace::from_workloads("unit test", 64, &sample_workloads());
        let text = t.render_jsonl();
        let back = Trace::parse_jsonl(&text).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.arrivals(), sample_workloads());
    }

    #[test]
    fn departures_sorted_by_slot() {
        let t = Trace::from_workloads("d", 64, &sample_workloads());
        // w1 departs at slot 2, w0 at slot 3.
        let deps: Vec<(u64, u64)> = t
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Departure(id, slot) => Some((*id, *slot)),
                _ => None,
            })
            .collect();
        assert_eq!(deps, vec![(1, 2), (0, 3)]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Trace::parse_jsonl("").is_err());
        assert!(Trace::parse_jsonl("{\"type\":\"arrival\"}").is_err());
        let bad_format = r#"{"type":"header","format":"v999","capacity_slices":8}"#;
        assert!(Trace::parse_jsonl(bad_format).is_err());
        let good_header =
            r#"{"type":"header","format":"migsched-trace-v1","capacity_slices":8}"#;
        let with_bad_event = format!("{good_header}\n{{\"type\":\"explode\"}}");
        assert!(Trace::parse_jsonl(&with_bad_event).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::from_workloads("file test", 800, &sample_workloads());
        let path = std::env::temp_dir()
            .join(format!("migsched-trace-{}.jsonl", std::process::id()));
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn generator_trace_replay_identity() {
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let g = gen.generate(800, &mut Rng::new(2024));
        let t = Trace::from_workloads("gen", 800, &g.workloads);
        let replayed = Trace::parse_jsonl(&t.render_jsonl()).unwrap().arrivals();
        assert_eq!(replayed, g.workloads);
    }
}
