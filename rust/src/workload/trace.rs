//! JSON-lines workload trace format: record synthetic runs, replay them
//! byte-identically, and import external traces into the simulator.
//!
//! Format: one JSON object per line. The first line is a header object
//! (`{"type":"header",...}`), subsequent lines are events. Two event kinds
//! exist — `arrival` carries the full workload spec, `departure` is
//! derivable from arrivals and optional. Departures are preserved
//! verbatim (so `save → load → save` is byte-stable) and **validated** on
//! load: a departure must reference a known arrival and agree with its
//! `arrival_slot + duration_slots`; contradictions (hand-edited files,
//! corrupt concatenations) are load errors, never silently ignored.

use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::Path;

use super::spec::Workload;
use crate::util::json::Json;
use crate::util::stats::Sample;

/// A trace event.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Arrival(Workload),
    /// (workload id, slot) — informational.
    Departure(u64, u64),
}

/// An in-memory workload trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Free-form description (distribution name, seed, generator version).
    pub description: String,
    /// Cluster capacity in slices the trace was generated against.
    pub capacity_slices: u64,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(description: &str, capacity_slices: u64) -> Self {
        Self { description: description.to_string(), capacity_slices, events: Vec::new() }
    }

    /// Build a trace from an arrival sequence (departures synthesized).
    pub fn from_workloads(
        description: &str,
        capacity_slices: u64,
        workloads: &[Workload],
    ) -> Self {
        let mut t = Self::new(description, capacity_slices);
        for w in workloads {
            t.events.push(TraceEvent::Arrival(*w));
        }
        // Synthesize departures in slot order for readability.
        let mut departures: Vec<(u64, u64)> =
            workloads.iter().map(|w| (w.id.0, w.departure_slot())).collect();
        departures.sort_by_key(|&(_, slot)| slot);
        for (id, slot) in departures {
            t.events.push(TraceEvent::Departure(id, slot));
        }
        t
    }

    /// The arrival sequence in arrival-slot order.
    pub fn arrivals(&self) -> Vec<Workload> {
        let mut ws: Vec<Workload> = self
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Arrival(w) => Some(*w),
                TraceEvent::Departure(..) => None,
            })
            .collect();
        ws.sort_by_key(|w| (w.arrival_slot, w.id));
        ws
    }

    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        let header = Json::obj()
            .with("type", "header")
            .with("format", "migsched-trace-v1")
            .with("description", self.description.as_str())
            .with("capacity_slices", self.capacity_slices);
        out.push_str(&header.to_string_compact());
        out.push('\n');
        for e in &self.events {
            let j = match e {
                TraceEvent::Arrival(w) => {
                    let mut j = w.to_json();
                    j.set("type", "arrival");
                    j
                }
                TraceEvent::Departure(id, slot) => Json::obj()
                    .with("type", "departure")
                    .with("id", *id)
                    .with("slot", *slot),
            };
            out.push_str(&j.to_string_compact());
            out.push('\n');
        }
        out
    }

    pub fn parse_jsonl(text: &str) -> Result<Trace, String> {
        // Enumerate PHYSICAL lines (1-based) so diagnostics on
        // hand-edited files with blank lines point at the right place.
        let mut lines = text.lines().enumerate();
        let (_, header_line) = lines
            .by_ref()
            .find(|(_, l)| !l.trim().is_empty())
            .ok_or("empty trace")?;
        let header = Json::parse(header_line).map_err(|e| format!("header: {e}"))?;
        if header.req_str("type")? != "header" {
            return Err("first line must be the header object".into());
        }
        let format = header.req_str("format")?;
        if format != "migsched-trace-v1" {
            return Err(format!("unsupported trace format '{format}'"));
        }
        let mut trace = Trace::new(
            header.get("description").and_then(Json::as_str).unwrap_or(""),
            header.req_u64("capacity_slices")?,
        );
        // Validation state: arrivals seen (id → expected departure slot);
        // departures are collected and checked in one post-pass (they may
        // legally precede their arrival line in hand-assembled files).
        let mut expected_departure: HashMap<u64, u64> = HashMap::new();
        let mut pending_departures: Vec<(u64, u64, usize)> = Vec::new();
        for (idx, line) in lines {
            if line.trim().is_empty() {
                continue;
            }
            let lineno = idx + 1; // physical, 1-based
            let j = Json::parse(line).map_err(|e| format!("line {lineno}: {e}"))?;
            match j.req_str("type")? {
                "arrival" => {
                    let w = Workload::from_json(&j)?;
                    // Untrusted input: the departure slot must be computed
                    // checked, or corrupt u64s panic in debug builds and
                    // wrap (poisoning the contradiction check) in release.
                    let departs =
                        w.arrival_slot.checked_add(w.duration_slots).ok_or_else(|| {
                            format!(
                                "line {lineno}: arrival_slot + duration_slots overflows \
                                 for id {}",
                                w.id.0
                            )
                        })?;
                    if expected_departure.insert(w.id.0, departs).is_some() {
                        return Err(format!("line {lineno}: duplicate arrival id {}", w.id.0));
                    }
                    trace.events.push(TraceEvent::Arrival(w));
                }
                "departure" => {
                    let (id, slot) = (j.req_u64("id")?, j.req_u64("slot")?);
                    pending_departures.push((id, slot, lineno));
                    trace.events.push(TraceEvent::Departure(id, slot));
                }
                other => return Err(format!("line {lineno}: unknown event '{other}'")),
            }
        }
        // Departures may precede their arrival line in hand-assembled
        // files, so contradictions are checked after the full pass.
        let mut departure_lines: HashMap<u64, usize> = HashMap::new();
        for (id, slot, lineno) in pending_departures {
            if let Some(prev) = departure_lines.insert(id, lineno) {
                return Err(format!(
                    "line {lineno}: duplicate departure for id {id} (first at line {prev})"
                ));
            }
            match expected_departure.get(&id) {
                None => {
                    return Err(format!(
                        "line {lineno}: departure for unknown workload id {id}"
                    ));
                }
                Some(&expected) if expected != slot => {
                    return Err(format!(
                        "line {lineno}: departure slot {slot} for id {id} contradicts \
                         its arrival (arrival_slot + duration_slots = {expected})"
                    ));
                }
                Some(_) => {}
            }
        }
        Ok(trace)
    }

    /// Summary statistics over the arrival sequence (the `migsched trace
    /// stats` view): profile histogram, inter-arrival and lifespan
    /// percentiles.
    pub fn stats(&self) -> TraceStats {
        TraceStats::compute(self)
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.render_jsonl().as_bytes())
    }

    pub fn load(path: &Path) -> Result<Trace, String> {
        let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let mut text = String::new();
        let mut reader = std::io::BufReader::new(f);
        loop {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Ok(_) => text.push_str(&line),
                Err(e) => return Err(format!("{}: {e}", path.display())),
            }
        }
        Self::parse_jsonl(&text)
    }
}

/// Percentile summary of one series.
#[derive(Clone, Copy, Debug, Default)]
pub struct SeriesStats {
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl SeriesStats {
    fn from_sample(sample: &mut Sample) -> SeriesStats {
        if sample.is_empty() {
            return SeriesStats::default();
        }
        SeriesStats {
            mean: sample.mean(),
            p50: sample.percentile(50.0),
            p90: sample.percentile(90.0),
            p99: sample.percentile(99.0),
            max: sample.max(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("mean", self.mean)
            .with("p50", self.p50)
            .with("p90", self.p90)
            .with("p99", self.p99)
            .with("max", self.max)
    }
}

/// Descriptive statistics of a trace's arrival sequence.
#[derive(Clone, Debug, Default)]
pub struct TraceStats {
    pub arrivals: u64,
    /// Inclusive slot count from first to last arrival (0 when empty) —
    /// the same definition as `ReplayResult::span_slots`, so the two
    /// join cleanly in reports.
    pub span_slots: u64,
    /// Distinct tenants attributed.
    pub tenants: usize,
    /// Arrival counts per profile, Table I order.
    pub profile_counts: [u64; crate::mig::NUM_PROFILES],
    /// Consecutive arrival-slot deltas (0 for same-slot bursts).
    pub inter_arrival_slots: SeriesStats,
    pub lifespan_slots: SeriesStats,
}

impl TraceStats {
    pub fn compute(trace: &Trace) -> TraceStats {
        let arrivals = trace.arrivals();
        let mut stats = TraceStats {
            arrivals: arrivals.len() as u64,
            ..TraceStats::default()
        };
        if arrivals.is_empty() {
            return stats;
        }
        stats.span_slots =
            arrivals.last().unwrap().arrival_slot - arrivals[0].arrival_slot + 1;
        let mut tenants: Vec<u32> = arrivals.iter().map(|w| w.tenant.0).collect();
        tenants.sort_unstable();
        tenants.dedup();
        stats.tenants = tenants.len();
        let mut inter = Sample::new();
        let mut life = Sample::new();
        for (i, w) in arrivals.iter().enumerate() {
            stats.profile_counts[w.profile.index()] += 1;
            life.push(w.duration_slots as f64);
            if i > 0 {
                inter.push((w.arrival_slot - arrivals[i - 1].arrival_slot) as f64);
            }
        }
        stats.inter_arrival_slots = SeriesStats::from_sample(&mut inter);
        stats.lifespan_slots = SeriesStats::from_sample(&mut life);
        stats
    }

    pub fn to_json(&self) -> Json {
        let mut profiles = Json::obj();
        for (i, &count) in self.profile_counts.iter().enumerate() {
            let p = crate::mig::Profile::from_index(i).unwrap();
            profiles.set(p.canonical_name(), count);
        }
        Json::obj()
            .with("arrivals", self.arrivals)
            .with("span_slots", self.span_slots)
            .with("tenants", self.tenants)
            .with("profiles", profiles)
            .with("inter_arrival_slots", self.inter_arrival_slots.to_json())
            .with("lifespan_slots", self.lifespan_slots.to_json())
    }

    /// Render as tables (profile histogram with bars + percentile rows).
    pub fn render(&self) -> String {
        use crate::util::table::Table;
        let mut out = String::new();
        let mut hist = Table::new(&["profile", "arrivals", "share", ""]);
        let total = self.arrivals.max(1);
        let max_count = self.profile_counts.iter().copied().max().unwrap_or(0).max(1);
        for (i, &count) in self.profile_counts.iter().enumerate() {
            let p = crate::mig::Profile::from_index(i).unwrap();
            let bar_len = (count * 24 / max_count) as usize;
            hist.row(&[
                p.canonical_name().to_string(),
                count.to_string(),
                format!("{:.1}%", count as f64 * 100.0 / total as f64),
                "#".repeat(bar_len),
            ]);
        }
        out.push_str(&hist.render());
        let mut series = Table::new(&["series", "mean", "p50", "p90", "p99", "max"]);
        for (name, s) in [
            ("inter-arrival (slots)", &self.inter_arrival_slots),
            ("lifespan (slots)", &self.lifespan_slots),
        ] {
            series.row(&[
                name.to_string(),
                format!("{:.2}", s.mean),
                format!("{:.1}", s.p50),
                format!("{:.1}", s.p90),
                format!("{:.1}", s.p99),
                format!("{:.0}", s.max),
            ]);
        }
        out.push_str(&format!(
            "arrivals: {}   span: {} slots   tenants: {}\n",
            self.arrivals, self.span_slots, self.tenants
        ));
        out.push_str(&series.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;
    use crate::workload::spec::{TenantId, WorkloadId};

    fn sample_workloads() -> Vec<Workload> {
        vec![
            Workload {
                id: WorkloadId(0),
                tenant: TenantId(0),
                profile: Profile::P2g20gb,
                arrival_slot: 0,
                duration_slots: 3,
            },
            Workload {
                id: WorkloadId(1),
                tenant: TenantId(1),
                profile: Profile::P7g80gb,
                arrival_slot: 1,
                duration_slots: 1,
            },
        ]
    }

    #[test]
    fn roundtrip() {
        let t = Trace::from_workloads("unit test", 64, &sample_workloads());
        let text = t.render_jsonl();
        let back = Trace::parse_jsonl(&text).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.arrivals(), sample_workloads());
    }

    #[test]
    fn departures_sorted_by_slot() {
        let t = Trace::from_workloads("d", 64, &sample_workloads());
        // w1 departs at slot 2, w0 at slot 3.
        let deps: Vec<(u64, u64)> = t
            .events
            .iter()
            .filter_map(|e| match e {
                TraceEvent::Departure(id, slot) => Some((*id, *slot)),
                _ => None,
            })
            .collect();
        assert_eq!(deps, vec![(1, 2), (0, 3)]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Trace::parse_jsonl("").is_err());
        assert!(Trace::parse_jsonl("{\"type\":\"arrival\"}").is_err());
        let bad_format = r#"{"type":"header","format":"v999","capacity_slices":8}"#;
        assert!(Trace::parse_jsonl(bad_format).is_err());
        let good_header =
            r#"{"type":"header","format":"migsched-trace-v1","capacity_slices":8}"#;
        let with_bad_event = format!("{good_header}\n{{\"type\":\"explode\"}}");
        assert!(Trace::parse_jsonl(&with_bad_event).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::from_workloads("file test", 800, &sample_workloads());
        let path = std::env::temp_dir()
            .join(format!("migsched-trace-{}.jsonl", std::process::id()));
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn save_load_save_is_byte_stable() {
        // Regression: departures are preserved on load (not dropped and
        // re-synthesized), so a second save emits identical bytes.
        let t = Trace::from_workloads("stability", 64, &sample_workloads());
        let first = t.render_jsonl();
        let second = Trace::parse_jsonl(&first).unwrap().render_jsonl();
        assert_eq!(first, second);
    }

    #[test]
    fn contradictory_departures_error_on_load() {
        let t = Trace::from_workloads("check", 64, &sample_workloads());
        let good = t.render_jsonl();
        // w0 arrives at slot 0 with duration 3 → departs at 3. Hand-edit
        // the departure line to slot 5: contradiction.
        let bad = good.replace("{\"type\":\"departure\",\"id\":0,\"slot\":3}",
                               "{\"type\":\"departure\",\"id\":0,\"slot\":5}");
        assert_ne!(good, bad, "fixture must actually change a line");
        let err = Trace::parse_jsonl(&bad).unwrap_err();
        assert!(err.contains("contradicts"), "{err}");

        // A departure for an id that never arrives is also an error.
        let ghost = format!("{good}{{\"type\":\"departure\",\"id\":99,\"slot\":4}}\n");
        let err = Trace::parse_jsonl(&ghost).unwrap_err();
        assert!(err.contains("unknown workload id 99"), "{err}");

        // Duplicate departures for one id are an error.
        let dup = format!("{good}{{\"type\":\"departure\",\"id\":0,\"slot\":3}}\n");
        let err = Trace::parse_jsonl(&dup).unwrap_err();
        assert!(err.contains("duplicate departure"), "{err}");

        // Duplicate arrival ids are an error.
        let arrival_line = good
            .lines()
            .find(|l| l.contains("\"type\":\"arrival\""))
            .unwrap();
        let dup_arrival = format!("{good}{arrival_line}\n");
        let err = Trace::parse_jsonl(&dup_arrival).unwrap_err();
        assert!(err.contains("duplicate arrival"), "{err}");
    }

    #[test]
    fn overflowing_slot_arithmetic_is_a_load_error() {
        let header = r#"{"type":"header","format":"migsched-trace-v1","capacity_slices":8}"#;
        let line = format!(
            "{{\"type\":\"arrival\",\"id\":0,\"tenant\":0,\"profile\":\"1g.10gb\",\
             \"arrival_slot\":{},\"duration_slots\":2}}",
            u64::MAX
        );
        let err = Trace::parse_jsonl(&format!("{header}\n{line}\n")).unwrap_err();
        assert!(err.contains("overflows"), "{err}");
    }

    #[test]
    fn departures_remain_optional() {
        // Arrival-only traces (what an external importer might produce
        // before synthesis) still load.
        let t = Trace::from_workloads("opt", 64, &sample_workloads());
        let arrivals_only: String = t
            .render_jsonl()
            .lines()
            .filter(|l| !l.contains("\"departure\""))
            .map(|l| format!("{l}\n"))
            .collect();
        let back = Trace::parse_jsonl(&arrivals_only).unwrap();
        assert_eq!(back.arrivals(), sample_workloads());
    }

    #[test]
    fn stats_histogram_and_percentiles() {
        let t = Trace::from_workloads("stats", 64, &sample_workloads());
        let s = t.stats();
        assert_eq!(s.arrivals, 2);
        // Inclusive: arrivals at slots 0 and 1 span 2 slots (matches
        // ReplayResult::span_slots on the same trace).
        assert_eq!(s.span_slots, 2);
        assert_eq!(s.tenants, 2);
        assert_eq!(s.profile_counts[Profile::P2g20gb.index()], 1);
        assert_eq!(s.profile_counts[Profile::P7g80gb.index()], 1);
        assert_eq!(s.profile_counts[Profile::P1g10gb.index()], 0);
        // Lifespans 3 and 1 → mean 2.
        assert!((s.lifespan_slots.mean - 2.0).abs() < 1e-12);
        assert!((s.inter_arrival_slots.mean - 1.0).abs() < 1e-12);
        let rendered = s.render();
        assert!(rendered.contains("2g.20gb"));
        assert!(rendered.contains("lifespan"));
        let j = s.to_json();
        assert_eq!(j.req_u64("arrivals").unwrap(), 2);
        // Empty trace stats don't panic.
        let empty = Trace::new("e", 8).stats();
        assert_eq!(empty.arrivals, 0);
        assert!(empty.render().lines().count() > 0);
    }

    #[test]
    fn generator_trace_replay_identity() {
        use crate::util::rng::Rng;
        use crate::workload::{Distribution, WorkloadGenerator};
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let g = gen.generate(800, &mut Rng::new(2024));
        let t = Trace::from_workloads("gen", 800, &g.workloads);
        let replayed = Trace::parse_jsonl(&t.render_jsonl()).unwrap().arrivals();
        assert_eq!(replayed, g.workloads);
    }
}
