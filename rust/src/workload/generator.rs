//! Synthetic workload generation following the paper's evaluation protocol
//! (Section VI):
//!
//! 1. one workload arrives per scheduling slot, its profile drawn i.i.d.
//!    from a Table II distribution;
//! 2. arrivals continue until the cumulative requested slice count reaches
//!    the cluster capacity — that arrival count defines the horizon `T`
//!    ("the number of scheduling slots required to saturate the cluster
//!    capacity");
//! 3. every workload's lifespan is then drawn uniformly from `[1, T]`
//!    slots, giving heterogeneous lifetimes synchronized with the
//!    scheduling procedure.

use super::distribution::Distribution;
use super::spec::{TenantId, Workload, WorkloadId};
use crate::util::rng::Rng;

/// Generator configuration + state.
#[derive(Clone, Debug)]
pub struct WorkloadGenerator {
    distribution: Distribution,
    /// Number of tenants to attribute requests to (round-robin attribution;
    /// tenancy does not influence scheduling, only accounting/isolation).
    num_tenants: u32,
}

/// The output of one generation pass: the arrival sequence and the horizon.
#[derive(Clone, Debug)]
pub struct GeneratedWorkloads {
    /// Workloads in arrival order; `workloads[t].arrival_slot == t`.
    pub workloads: Vec<Workload>,
    /// The saturation horizon `T` (== `workloads.len()`).
    pub horizon: u64,
    /// Total requested slices (≥ capacity by construction).
    pub total_slices: u64,
}

impl WorkloadGenerator {
    pub fn new(distribution: Distribution) -> Self {
        Self { distribution, num_tenants: 1 }
    }

    pub fn with_tenants(mut self, n: u32) -> Self {
        assert!(n > 0, "need at least one tenant");
        self.num_tenants = n;
        self
    }

    pub fn distribution(&self) -> &Distribution {
        &self.distribution
    }

    /// Generate the paper's arrival sequence for a cluster with
    /// `capacity_slices` total slices (M GPUs × 8).
    ///
    /// Durations are assigned in a second pass because `T` is only known
    /// once the cumulative demand reaches capacity.
    pub fn generate(&self, capacity_slices: u64, rng: &mut Rng) -> GeneratedWorkloads {
        assert!(capacity_slices > 0);
        let sampler = self.distribution.sampler();

        // Pass 1: arrivals until saturation.
        let mut profiles = Vec::new();
        let mut total: u64 = 0;
        while total < capacity_slices {
            let p = sampler.sample(rng);
            total += p.size() as u64;
            profiles.push(p);
        }
        let horizon = profiles.len() as u64;

        // Pass 2: lifespans ~ U[1, T], tenants round-robin.
        let workloads = profiles
            .into_iter()
            .enumerate()
            .map(|(t, profile)| Workload {
                id: WorkloadId(t as u64),
                tenant: TenantId(t as u32 % self.num_tenants),
                profile,
                arrival_slot: t as u64,
                duration_slots: rng.range_inclusive(1, horizon),
            })
            .collect();

        GeneratedWorkloads { workloads, horizon, total_slices: total }
    }

    /// Generate an *open-ended* stream for the serving daemon's load
    /// generator: `n` workloads with exponential(λ) inter-arrival times
    /// mapped onto integer slots, durations U[1, max_duration].
    pub fn generate_stream(
        &self,
        n: usize,
        mean_interarrival_slots: f64,
        max_duration: u64,
        rng: &mut Rng,
    ) -> Vec<Workload> {
        assert!(mean_interarrival_slots > 0.0 && max_duration >= 1);
        let sampler = self.distribution.sampler();
        let mut slot_f = 0.0f64;
        (0..n)
            .map(|i| {
                slot_f += rng.exponential(1.0 / mean_interarrival_slots);
                Workload {
                    id: WorkloadId(i as u64),
                    tenant: TenantId(i as u32 % self.num_tenants),
                    profile: sampler.sample(rng),
                    arrival_slot: slot_f as u64,
                    duration_slots: rng.range_inclusive(1, max_duration),
                }
            })
            .collect()
    }
}

impl GeneratedWorkloads {
    /// Cumulative requested slices after each arrival — used to locate
    /// the paper's "GPU demand" checkpoints (50% = the slot where the
    /// running sum crosses half the capacity).
    pub fn cumulative_slices(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.workloads.len());
        let mut acc = 0u64;
        for w in &self.workloads {
            acc += w.slices() as u64;
            out.push(acc);
        }
        out
    }

    /// First slot index at which cumulative demand reaches
    /// `fraction` × capacity (fraction in (0, 1]).
    pub fn demand_checkpoint_slot(&self, capacity_slices: u64, fraction: f64) -> u64 {
        assert!(fraction > 0.0 && fraction <= 1.0);
        let target = (capacity_slices as f64 * fraction).ceil() as u64;
        let mut acc = 0u64;
        for w in &self.workloads {
            acc += w.slices() as u64;
            if acc >= target {
                return w.arrival_slot;
            }
        }
        self.horizon.saturating_sub(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ALL_PROFILES;

    #[test]
    fn saturates_capacity_exactly_once() {
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let mut rng = Rng::new(1);
        let g = gen.generate(800, &mut rng);
        assert!(g.total_slices >= 800);
        // Removing the last arrival drops below capacity (minimality).
        let last = g.workloads.last().unwrap();
        assert!(g.total_slices - last.slices() as u64 <= 800);
        assert_eq!(g.horizon, g.workloads.len() as u64);
    }

    #[test]
    fn arrival_slots_are_consecutive() {
        let gen = WorkloadGenerator::new(Distribution::SkewSmall);
        let mut rng = Rng::new(2);
        let g = gen.generate(800, &mut rng);
        for (t, w) in g.workloads.iter().enumerate() {
            assert_eq!(w.arrival_slot, t as u64);
            assert_eq!(w.id, WorkloadId(t as u64));
        }
    }

    #[test]
    fn durations_within_horizon() {
        let gen = WorkloadGenerator::new(Distribution::Bimodal);
        let mut rng = Rng::new(3);
        let g = gen.generate(800, &mut rng);
        for w in &g.workloads {
            assert!(w.duration_slots >= 1 && w.duration_slots <= g.horizon, "{w:?}");
        }
    }

    #[test]
    fn horizon_tracks_mean_profile_size() {
        // skew-small needs many more arrivals to saturate than skew-big.
        let mut rng = Rng::new(4);
        let small =
            WorkloadGenerator::new(Distribution::SkewSmall).generate(8000, &mut rng).horizon;
        let big =
            WorkloadGenerator::new(Distribution::SkewBig).generate(8000, &mut rng).horizon;
        // E[slices]: skew-small 2.4, skew-big 4.65 → ratio ≈ 1.94.
        assert!(small as f64 > big as f64 * 1.8, "small={small} big={big}");
        // And both roughly match capacity / E[slices].
        let expect_small = 8000.0 / Distribution::SkewSmall.mean_slices();
        assert!((small as f64 - expect_small).abs() / expect_small < 0.1);
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let a = gen.generate(800, &mut Rng::new(99));
        let b = gen.generate(800, &mut Rng::new(99));
        assert_eq!(a.workloads, b.workloads);
    }

    #[test]
    fn tenants_round_robin() {
        let gen = WorkloadGenerator::new(Distribution::Uniform).with_tenants(3);
        let g = gen.generate(200, &mut Rng::new(5));
        for w in &g.workloads {
            assert_eq!(w.tenant.0, w.id.0 as u32 % 3);
        }
    }

    #[test]
    fn cumulative_and_checkpoints() {
        let gen = WorkloadGenerator::new(Distribution::Uniform);
        let g = gen.generate(800, &mut Rng::new(10));
        let cum = g.cumulative_slices();
        assert_eq!(cum.len(), g.workloads.len());
        assert!(cum.windows(2).all(|w| w[1] > w[0]));
        let half = g.demand_checkpoint_slot(800, 0.5);
        assert!(cum[half as usize] >= 400);
        assert!(half == 0 || cum[half as usize - 1] < 400);
        let full = g.demand_checkpoint_slot(800, 1.0);
        assert_eq!(full, g.horizon - 1);
    }

    #[test]
    fn stream_generation() {
        let gen = WorkloadGenerator::new(Distribution::Uniform).with_tenants(4);
        let mut rng = Rng::new(11);
        let ws = gen.generate_stream(500, 2.0, 50, &mut rng);
        assert_eq!(ws.len(), 500);
        // Arrivals are non-decreasing.
        assert!(ws.windows(2).all(|p| p[0].arrival_slot <= p[1].arrival_slot));
        // All profiles eventually appear.
        for p in ALL_PROFILES {
            assert!(ws.iter().any(|w| w.profile == p), "{p}");
        }
        // Mean inter-arrival roughly 2 slots.
        let span = ws.last().unwrap().arrival_slot as f64;
        assert!((span / 500.0 - 2.0).abs() < 0.4, "span={span}");
    }
}
