//! The paper's Table II MIG-profile request distributions.

use crate::mig::profile::{Profile, ALL_PROFILES, NUM_PROFILES};
use crate::util::rng::{AliasTable, Rng};

/// A probability distribution over the six MIG profile shapes.
///
/// The four named distributions are Table II verbatim; `Custom` supports
/// user-supplied mixes via config/CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Distribution {
    /// Every profile equally likely — the paper's baseline scenario.
    Uniform,
    /// Small profiles dominate: severe fragmentation pressure.
    SkewSmall,
    /// Large profiles dominate: rigid placements, less fragmentation head-room.
    SkewBig,
    /// Mixture of large and small profiles with conflicting constraints.
    Bimodal,
    /// User-supplied probabilities in Table I profile order.
    Custom([f64; NUM_PROFILES]),
}

impl Distribution {
    /// Table II probability density, in Table I profile order
    /// (7g.80gb, 4g.40gb, 3g.40gb, 2g.20gb, 1g.20gb, 1g.10gb).
    pub fn pdf(&self) -> [f64; NUM_PROFILES] {
        match self {
            Distribution::Uniform => [1.0 / 6.0; 6],
            Distribution::SkewSmall => [0.05, 0.10, 0.10, 0.20, 0.25, 0.30],
            Distribution::SkewBig => [0.30, 0.25, 0.20, 0.10, 0.10, 0.05],
            Distribution::Bimodal => [0.30, 0.15, 0.05, 0.05, 0.15, 0.30],
            Distribution::Custom(p) => *p,
        }
    }

    /// The four named Table II distributions, in paper order.
    pub fn paper_set() -> [Distribution; 4] {
        [
            Distribution::Uniform,
            Distribution::SkewSmall,
            Distribution::SkewBig,
            Distribution::Bimodal,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::SkewSmall => "skew-small",
            Distribution::SkewBig => "skew-big",
            Distribution::Bimodal => "bimodal",
            Distribution::Custom(_) => "custom",
        }
    }

    pub fn parse(s: &str) -> Option<Distribution> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "uniform" => Some(Distribution::Uniform),
            "skew-small" | "skewsmall" | "small" => Some(Distribution::SkewSmall),
            "skew-big" | "skewbig" | "big" => Some(Distribution::SkewBig),
            "bimodal" => Some(Distribution::Bimodal),
            _ => None,
        }
    }

    /// Build a custom distribution; weights are normalized. Errors when
    /// the arity is wrong or the sum is non-positive.
    pub fn custom(weights: &[f64]) -> Result<Distribution, String> {
        if weights.len() != NUM_PROFILES {
            return Err(format!("need {NUM_PROFILES} weights, got {}", weights.len()));
        }
        let sum: f64 = weights.iter().sum();
        if !(sum > 0.0 && sum.is_finite()) || weights.iter().any(|w| *w < 0.0) {
            return Err("weights must be non-negative with positive finite sum".into());
        }
        let mut p = [0.0; NUM_PROFILES];
        for (i, w) in weights.iter().enumerate() {
            p[i] = w / sum;
        }
        Ok(Distribution::Custom(p))
    }

    /// O(1) sampler for this distribution.
    pub fn sampler(&self) -> ProfileSampler {
        ProfileSampler { alias: AliasTable::new(&self.pdf()) }
    }

    /// Expected slice footprint of one request — determines how many
    /// arrivals saturate a cluster (`T ≈ capacity / E[slices]`).
    pub fn mean_slices(&self) -> f64 {
        self.pdf()
            .iter()
            .zip(ALL_PROFILES.iter())
            .map(|(p, prof)| p * prof.size() as f64)
            .sum()
    }
}

impl std::fmt::Display for Distribution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Precomputed alias-method sampler over profiles.
#[derive(Clone, Debug)]
pub struct ProfileSampler {
    alias: AliasTable,
}

impl ProfileSampler {
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> Profile {
        ALL_PROFILES[self.alias.sample(rng)]
    }
}

/// Render Table II (the `inspect --distributions` CLI output).
pub fn table_ii() -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(&[
        "MIG profile", "uniform", "skew-small", "skew-big", "bimodal",
    ])
    .title("MIG profile distributions (paper Table II)");
    let dists = Distribution::paper_set();
    for (i, p) in ALL_PROFILES.iter().enumerate() {
        let mut row = vec![p.canonical_name().to_string()];
        for d in &dists {
            row.push(format!("{:.4}", d.pdf()[i]));
        }
        t.row(&row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II, asserted verbatim (experiment id T2 in DESIGN.md §4).
    #[test]
    fn table_ii_data() {
        assert_eq!(Distribution::SkewSmall.pdf(), [0.05, 0.10, 0.10, 0.20, 0.25, 0.30]);
        assert_eq!(Distribution::SkewBig.pdf(), [0.30, 0.25, 0.20, 0.10, 0.10, 0.05]);
        assert_eq!(Distribution::Bimodal.pdf(), [0.30, 0.15, 0.05, 0.05, 0.15, 0.30]);
        for d in Distribution::paper_set() {
            let sum: f64 = d.pdf().iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "{d} sums to {sum}");
        }
    }

    #[test]
    fn mean_slices_ordering() {
        // skew-big requests more slices per workload than skew-small.
        assert!(Distribution::SkewBig.mean_slices() > Distribution::Uniform.mean_slices());
        assert!(Distribution::Uniform.mean_slices() > Distribution::SkewSmall.mean_slices());
        // Uniform: (8+4+4+2+2+1)/6 = 3.5.
        assert!((Distribution::Uniform.mean_slices() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn sampler_matches_pdf() {
        let d = Distribution::Bimodal;
        let sampler = d.sampler();
        let mut rng = Rng::new(7);
        let trials = 120_000;
        let mut counts = [0f64; NUM_PROFILES];
        for _ in 0..trials {
            counts[sampler.sample(&mut rng).index()] += 1.0;
        }
        for (i, &p) in d.pdf().iter().enumerate() {
            let freq = counts[i] / trials as f64;
            assert!((freq - p).abs() < 0.01, "profile {i}: {freq} vs {p}");
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(Distribution::parse("uniform"), Some(Distribution::Uniform));
        assert_eq!(Distribution::parse("skew_small"), Some(Distribution::SkewSmall));
        assert_eq!(Distribution::parse("SKEW-BIG"), Some(Distribution::SkewBig));
        assert_eq!(Distribution::parse("bimodal"), Some(Distribution::Bimodal));
        assert_eq!(Distribution::parse("zipf"), None);
    }

    #[test]
    fn custom_normalizes() {
        let d = Distribution::custom(&[1.0, 1.0, 0.0, 0.0, 0.0, 2.0]).unwrap();
        let pdf = d.pdf();
        assert!((pdf[0] - 0.25).abs() < 1e-12);
        assert!((pdf[5] - 0.5).abs() < 1e-12);
        assert!(Distribution::custom(&[1.0]).is_err());
        assert!(Distribution::custom(&[0.0; 6]).is_err());
        assert!(Distribution::custom(&[-1.0, 2.0, 0.0, 0.0, 0.0, 0.0]).is_err());
    }

    #[test]
    fn table_ii_renders() {
        let s = table_ii().render();
        assert!(s.contains("skew-small"));
        assert!(s.contains("1g.10gb"));
        assert!(s.contains("0.3000"));
    }
}
