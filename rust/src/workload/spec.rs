//! Workload descriptors.

use crate::mig::Profile;
use crate::util::json::Json;

/// Unique workload identifier (assigned by generator / API, monotone).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkloadId(pub u64);

impl std::fmt::Display for WorkloadId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "w{}", self.0)
    }
}

/// Tenant identifier — the multi-tenant dimension of the serving daemon.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u32);

impl std::fmt::Display for TenantId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// A workload request: one MIG profile, an arrival slot, and a lifespan in
/// scheduling slots (paper Section IV: `r_w(p)` plus timing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Workload {
    pub id: WorkloadId,
    pub tenant: TenantId,
    /// Requested MIG profile `p ∈ P`.
    pub profile: Profile,
    /// Arrival scheduling slot (one arrival per slot in the paper's model).
    pub arrival_slot: u64,
    /// Lifespan in scheduling slots, sampled from U[1, T].
    pub duration_slots: u64,
}

impl Workload {
    /// Slot at which the workload terminates and releases its slices
    /// (exclusive: resources free at the *start* of this slot).
    pub fn departure_slot(&self) -> u64 {
        self.arrival_slot + self.duration_slots
    }

    /// Requested slice count — the `r_w(p)` resource vector collapses to
    /// the memory-slice footprint in the 8-position model.
    pub fn slices(&self) -> u8 {
        self.profile.size()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("id", self.id.0)
            .with("tenant", self.tenant.0 as u64)
            .with("profile", self.profile.canonical_name())
            .with("arrival_slot", self.arrival_slot)
            .with("duration_slots", self.duration_slots)
    }

    pub fn from_json(j: &Json) -> Result<Workload, String> {
        let profile_name = j.req_str("profile")?;
        let profile = Profile::parse(profile_name)
            .ok_or_else(|| format!("unknown profile '{profile_name}'"))?;
        Ok(Workload {
            id: WorkloadId(j.req_u64("id")?),
            tenant: TenantId(j.req_u64("tenant").unwrap_or(0) as u32),
            profile,
            arrival_slot: j.req_u64("arrival_slot")?,
            duration_slots: j.req_u64("duration_slots")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Workload {
        Workload {
            id: WorkloadId(17),
            tenant: TenantId(3),
            profile: Profile::P3g40gb,
            arrival_slot: 42,
            duration_slots: 10,
        }
    }

    #[test]
    fn departure_and_slices() {
        let w = sample();
        assert_eq!(w.departure_slot(), 52);
        assert_eq!(w.slices(), 4);
    }

    #[test]
    fn json_roundtrip() {
        let w = sample();
        let j = w.to_json();
        assert_eq!(Workload::from_json(&j).unwrap(), w);
    }

    #[test]
    fn json_rejects_bad_profile() {
        let j = sample().to_json();
        let mut j2 = j.clone();
        j2.set("profile", "42g.1gb");
        assert!(Workload::from_json(&j2).is_err());
    }

    #[test]
    fn json_tenant_defaults_to_zero() {
        let j = Json::obj()
            .with("id", 1u64)
            .with("profile", "1g.10gb")
            .with("arrival_slot", 0u64)
            .with("duration_slots", 5u64);
        assert_eq!(Workload::from_json(&j).unwrap().tenant, TenantId(0));
    }

    #[test]
    fn display_forms() {
        assert_eq!(WorkloadId(9).to_string(), "w9");
        assert_eq!(TenantId(2).to_string(), "t2");
    }
}
