//! The supported raw trace schemas and their row parsers.
//!
//! Both importers are **header-driven**: the first non-empty line names the
//! columns, so column order is free and unknown columns are ignored — the
//! tolerance real exports need (the public dumps ship with supersets of the
//! documented schemas). Each data row parses independently into a
//! [`RawJob`] or a row-local error; one bad row never aborts the file.
//!
//! * **Alibaba** — `cluster-trace-gpu-v2020` task-table style. Required
//!   columns: `job_name`, `status`, `start_time`, `end_time`, `plan_gpu`
//!   (percent of one GPU: 50 = half). Optional: `plan_mem` (GB),
//!   `inst_num` (instance count; a row expands into that many workloads),
//!   `user` (tenant attribution). Only `Terminated` rows are imported —
//!   other statuses lack a meaningful start/end pair and are counted as
//!   filtered.
//! * **Philly** — Microsoft Philly job-log style. Required: `jobid`,
//!   `status`, `start_time`, `finished_time`, `num_gpus` (device count).
//!   Optional: `vc` (tenant), `mem_gb`, `submitted_time`. Single-device
//!   jobs with an explicit `mem_gb` are sized by the memory request
//!   (MIG-ifying a whole-device cluster); single-device jobs without one
//!   pin a full GPU, and multi-device jobs expand into one full-GPU
//!   workload per device so their demand is preserved.
//!   `Pass`/`Killed`/`Failed` rows all occupied GPUs for their lifetime,
//!   so all three import; rows that never started (empty start/finish)
//!   are filtered.
//!
//! Timestamps accept integer/float epoch seconds or
//! `YYYY-MM-DD HH:MM:SS` wall-clock datetimes (Philly's native form).

use std::collections::HashMap;

use crate::util::csv;

/// The raw trace dialect to parse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    Alibaba,
    Philly,
}

impl TraceFormat {
    pub fn name(self) -> &'static str {
        match self {
            TraceFormat::Alibaba => "alibaba",
            TraceFormat::Philly => "philly",
        }
    }

    pub fn parse(s: &str) -> Option<TraceFormat> {
        match s.to_ascii_lowercase().as_str() {
            "alibaba" | "alibaba-v2020" | "pai" => Some(TraceFormat::Alibaba),
            "philly" | "msr-philly" => Some(TraceFormat::Philly),
            _ => None,
        }
    }
}

impl std::fmt::Display for TraceFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One job extracted from a raw trace row, before profile mapping and
/// time normalization. `gpu_share` is the fraction of one GPU (Philly's
/// multi-device jobs exceed 1.0), `mem_gb` the GPU memory request
/// (0 = unconstrained), times are wall-clock epoch seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct RawJob {
    pub key: String,
    pub tenant: u32,
    pub gpu_share: f64,
    pub mem_gb: f64,
    pub start: u64,
    pub end: u64,
}

/// Per-row parse outcome. Expansion (`inst_num`, multi-device jobs) is
/// expressed as a count, not materialized clones — million-row imports
/// should not allocate N identical structs per row.
#[derive(Clone, Debug, PartialEq)]
pub enum RowOutcome {
    /// `count` identical workloads described by one [`RawJob`].
    Jobs(RawJob, usize),
    /// Dropped by the status filter (not a ran-to-completion state, or
    /// never scheduled).
    FilteredStatus,
    /// Dropped because the row requests no GPU at all (CPU-only tasks —
    /// a large share of the real Alibaba dump).
    FilteredNoGpu,
}

/// Column-name → position lookup built from the header line.
pub struct Header {
    index: HashMap<String, usize>,
}

impl Header {
    /// Parse the header row; errors if a required column is missing.
    pub fn parse(line: &str, required: &[&str]) -> Result<Header, String> {
        let cells = csv::parse_line(line).map_err(|e| format!("header: {e}"))?;
        let mut index = HashMap::new();
        for (i, name) in cells.iter().enumerate() {
            index.insert(name.trim().to_ascii_lowercase(), i);
        }
        for col in required {
            if !index.contains_key(*col) {
                return Err(format!("header is missing required column '{col}'"));
            }
        }
        Ok(Header { index })
    }

    fn get<'a>(&self, cells: &'a [String], col: &str) -> Option<&'a str> {
        self.index.get(col).and_then(|&i| cells.get(i)).map(|s| s.trim())
    }

    /// Required field: present and non-empty.
    fn req<'a>(&self, cells: &'a [String], col: &str) -> Result<&'a str, String> {
        match self.get(cells, col) {
            Some(v) if !v.is_empty() => Ok(v),
            _ => Err(format!("missing value for column '{col}'")),
        }
    }
}

/// Cap on one Alibaba row's `inst_num` expansion (anti-balloon bound; the
/// public trace's largest tasks run a few hundred instances).
pub const MAX_INST_NUM: usize = 4096;

/// Columns the Alibaba dialect requires.
pub const ALIBABA_REQUIRED: [&str; 5] =
    ["job_name", "status", "start_time", "end_time", "plan_gpu"];

/// Columns the Philly dialect requires.
pub const PHILLY_REQUIRED: [&str; 5] =
    ["jobid", "status", "start_time", "finished_time", "num_gpus"];

impl TraceFormat {
    /// The required-column set for [`Header::parse`].
    pub fn required_columns(self) -> &'static [&'static str] {
        match self {
            TraceFormat::Alibaba => &ALIBABA_REQUIRED,
            TraceFormat::Philly => &PHILLY_REQUIRED,
        }
    }

    /// Parse one data row (already CSV-split) against a parsed header.
    pub fn parse_row(self, header: &Header, cells: &[String]) -> Result<RowOutcome, String> {
        match self {
            TraceFormat::Alibaba => parse_alibaba_row(header, cells),
            TraceFormat::Philly => parse_philly_row(header, cells),
        }
    }
}

fn parse_f64(what: &str, v: &str) -> Result<f64, String> {
    v.parse::<f64>().map_err(|_| format!("bad number '{v}' for {what}"))
}

fn parse_alibaba_row(header: &Header, cells: &[String]) -> Result<RowOutcome, String> {
    let status = header.req(cells, "status")?;
    if !status.eq_ignore_ascii_case("terminated") {
        return Ok(RowOutcome::FilteredStatus);
    }
    // plan_gpu is percent of one GPU (Alibaba convention: 100 = 1
    // device). In the real dump it is EMPTY (or 0) for CPU-only tasks —
    // those are a filter category, not corruption; a row truncated
    // before the cell is.
    let plan_gpu = match header.get(cells, "plan_gpu") {
        None => return Err("truncated row: missing plan_gpu cell".into()),
        Some("") => return Ok(RowOutcome::FilteredNoGpu),
        Some(v) => parse_f64("plan_gpu", v)?,
    };
    if plan_gpu == 0.0 {
        return Ok(RowOutcome::FilteredNoGpu);
    }
    let key = header.req(cells, "job_name")?.to_string();
    let start_raw = header.req(cells, "start_time")?;
    let start =
        parse_timestamp(start_raw).ok_or_else(|| format!("bad start_time '{start_raw}'"))?;
    let end_raw = header.req(cells, "end_time")?;
    let end = parse_timestamp(end_raw).ok_or_else(|| format!("bad end_time '{end_raw}'"))?;
    let gpu_share = plan_gpu / 100.0;
    let mem_gb = match header.get(cells, "plan_mem") {
        Some(v) if !v.is_empty() => parse_f64("plan_mem", v)?,
        _ => 0.0,
    };
    let inst_num = match header.get(cells, "inst_num") {
        Some(v) if !v.is_empty() => {
            let n = parse_f64("inst_num", v)?;
            // Bounded so one corrupt row cannot balloon the import: the
            // real trace tops out at hundreds of instances per task.
            if !n.is_finite() || n < 1.0 || n > MAX_INST_NUM as f64 {
                return Err(format!("bad inst_num '{v}' (allowed 1..={MAX_INST_NUM})"));
            }
            n as usize
        }
        _ => 1,
    };
    let tenant = match header.get(cells, "user") {
        Some(v) if !v.is_empty() => tenant_hash(v),
        _ => tenant_hash(&key),
    };
    let job = RawJob { key, tenant, gpu_share, mem_gb, start, end };
    Ok(RowOutcome::Jobs(job, inst_num))
}

fn parse_philly_row(header: &Header, cells: &[String]) -> Result<RowOutcome, String> {
    let status = header.req(cells, "status")?;
    let known = ["pass", "killed", "failed"]
        .iter()
        .any(|s| status.eq_ignore_ascii_case(s));
    if !known {
        return Ok(RowOutcome::FilteredStatus);
    }
    let key = header.req(cells, "jobid")?.to_string();
    // Killed/Failed jobs that never got scheduled carry EMPTY start/finish
    // cells in the real Philly log — they never occupied a GPU, so they
    // are filtered like foreign statuses. A row truncated before the
    // cells (no comma at all) is corrupt, not filtered.
    let start_raw = match header.get(cells, "start_time") {
        None => return Err("truncated row: missing start_time cell".into()),
        Some(v) => v,
    };
    let end_raw = match header.get(cells, "finished_time") {
        None => return Err("truncated row: missing finished_time cell".into()),
        Some(v) => v,
    };
    if start_raw.is_empty() || end_raw.is_empty() {
        return Ok(RowOutcome::FilteredStatus);
    }
    let start =
        parse_timestamp(start_raw).ok_or_else(|| format!("bad start_time '{start_raw}'"))?;
    let end =
        parse_timestamp(end_raw).ok_or_else(|| format!("bad finished_time '{end_raw}'"))?;
    let num_gpus = parse_f64("num_gpus", header.req(cells, "num_gpus")?)?;
    // Validated here, not in the mapper: the share transform below would
    // otherwise fold a negative device count into a valid-looking 0.0.
    if !num_gpus.is_finite() || num_gpus < 0.0 {
        return Err(format!("bad num_gpus '{num_gpus}'"));
    }
    let mem_gb = match header.get(cells, "mem_gb") {
        Some(v) if !v.is_empty() => parse_f64("mem_gb", v)?,
        _ => 0.0,
    };
    // Philly requests whole devices — the granularity of a non-MIG
    // cluster, not real demand. A single-GPU job with an explicit memory
    // request is sized by that request (share 0 = compute-unconstrained,
    // the mapper picks the smallest profile covering the memory); a
    // single-GPU job without one pins a full GPU. Multi-device jobs
    // expand into one full-GPU workload per device (like Alibaba's
    // `inst_num`) so an 8-GPU job carries 8 GPUs of demand into the
    // replay instead of collapsing to one clamped profile.
    // Fallback mirrors the Alibaba importer: no vc column → hash the job
    // key, so tenant structure never collapses onto one shard.
    let tenant = match header.get(cells, "vc") {
        Some(v) if !v.is_empty() => tenant_hash(v),
        _ => tenant_hash(&key),
    };
    if num_gpus == 0.0 {
        return Ok(RowOutcome::FilteredNoGpu);
    }
    if num_gpus > 1.0 {
        // Multi-device counts must be whole devices — truncating 1.5
        // would silently drop half a GPU of demand.
        if num_gpus.fract() != 0.0 {
            return Err(format!("bad num_gpus '{num_gpus}' (fractional device count)"));
        }
        let count = num_gpus as usize;
        if count > MAX_INST_NUM {
            return Err(format!("bad num_gpus '{num_gpus}' (allowed up to {MAX_INST_NUM})"));
        }
        let job = RawJob { key, tenant, gpu_share: 1.0, mem_gb: 0.0, start, end };
        return Ok(RowOutcome::Jobs(job, count));
    }
    let gpu_share = if mem_gb > 0.0 { 0.0 } else { num_gpus };
    let job = RawJob { key, tenant, gpu_share, mem_gb, start, end };
    Ok(RowOutcome::Jobs(job, 1))
}

/// Stable tenant attribution from a user/VC string (FNV-1a, folded to the
/// `TenantId` width). Deterministic across runs and platforms so ingest
/// output is byte-reproducible.
pub fn tenant_hash(s: &str) -> u32 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    (h ^ (h >> 32)) as u32
}

/// Parse a trace timestamp: non-negative integer/float epoch seconds, or a
/// `YYYY-MM-DD HH:MM:SS` (space or `T` separator) civil datetime mapped to
/// epoch seconds (UTC). Returns `None` for anything else.
pub fn parse_timestamp(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    if let Ok(v) = s.parse::<u64>() {
        return Some(v);
    }
    if let Ok(v) = s.parse::<f64>() {
        if v.is_finite() && v >= 0.0 {
            return Some(v as u64);
        }
        return None;
    }
    parse_datetime(s)
}

/// `YYYY-MM-DD[ T]HH:MM:SS` → epoch seconds (proleptic Gregorian, UTC).
fn parse_datetime(s: &str) -> Option<u64> {
    if s.len() != 19 {
        return None;
    }
    let bytes = s.as_bytes();
    let sep = bytes[10];
    if bytes[4] != b'-' || bytes[7] != b'-' || (sep != b' ' && sep != b'T') {
        return None;
    }
    if bytes[13] != b':' || bytes[16] != b':' {
        return None;
    }
    let num = |range: std::ops::Range<usize>| -> Option<u64> {
        let part = &s[range];
        if !part.bytes().all(|b| b.is_ascii_digit()) {
            return None;
        }
        part.parse().ok()
    };
    let (y, m, d) = (num(0..4)?, num(5..7)?, num(8..10)?);
    let (hh, mm, ss) = (num(11..13)?, num(14..16)?, num(17..19)?);
    if !(1..=12).contains(&m) || !(1..=31).contains(&d) || hh > 23 || mm > 59 || ss > 59 {
        return None;
    }
    let days = days_from_civil(y as i64, m, d);
    if days < 0 {
        return None; // pre-epoch timestamps are not valid trace times
    }
    Some(days as u64 * 86_400 + hh * 3600 + mm * 60 + ss)
}

/// Days since 1970-01-01 for a civil date (Howard Hinnant's algorithm).
fn days_from_civil(y: i64, m: u64, d: u64) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = (y - era * 400) as u64; // [0, 399]
    let mp = if m > 2 { m - 3 } else { m + 9 }; // [0, 11], March-based
    let doy = (153 * mp + 2) / 5 + d - 1; // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146_097 + doe as i64 - 719_468
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cells(s: &str) -> Vec<String> {
        csv::parse_line(s).unwrap()
    }

    #[test]
    fn format_parse_roundtrip() {
        for f in [TraceFormat::Alibaba, TraceFormat::Philly] {
            assert_eq!(TraceFormat::parse(f.name()), Some(f));
        }
        assert_eq!(TraceFormat::parse("PAI"), Some(TraceFormat::Alibaba));
        assert_eq!(TraceFormat::parse("borg"), None);
    }

    #[test]
    fn timestamps_epoch_and_datetime() {
        assert_eq!(parse_timestamp("0"), Some(0));
        assert_eq!(parse_timestamp(" 4550 "), Some(4550));
        assert_eq!(parse_timestamp("4550.75"), Some(4550));
        assert_eq!(parse_timestamp("1970-01-01 00:00:00"), Some(0));
        assert_eq!(parse_timestamp("1970-01-02T00:00:01"), Some(86_401));
        // Pinned against `date -u -d '2017-10-03 11:22:43' +%s`.
        assert_eq!(parse_timestamp("2017-10-03 11:22:43"), Some(1_507_029_763));
        assert_eq!(parse_timestamp(""), None);
        assert_eq!(parse_timestamp("-5"), None);
        assert_eq!(parse_timestamp("2017-13-01 00:00:00"), None);
        assert_eq!(parse_timestamp("2017-10-03 24:00:00"), None);
        assert_eq!(parse_timestamp("yesterday"), None);
        assert_eq!(parse_timestamp("2017-10-03"), None);
    }

    #[test]
    fn days_from_civil_epoch_anchors() {
        assert_eq!(days_from_civil(1970, 1, 1), 0);
        assert_eq!(days_from_civil(1970, 1, 2), 1);
        assert_eq!(days_from_civil(2000, 3, 1), 11_017);
        assert_eq!(days_from_civil(1969, 12, 31), -1);
    }

    #[test]
    fn alibaba_row_parses_and_filters() {
        let header = Header::parse(
            "job_name,task_name,inst_num,status,start_time,end_time,plan_cpu,plan_mem,plan_gpu,gpu_type",
            &ALIBABA_REQUIRED,
        )
        .unwrap();
        let row = cells("j1,tensorflow,1,Terminated,1000,2000,600,29.0,50,V100");
        match TraceFormat::Alibaba.parse_row(&header, &row).unwrap() {
            RowOutcome::Jobs(j, count) => {
                assert_eq!(count, 1);
                assert_eq!(j.key, "j1");
                assert!((j.gpu_share - 0.5).abs() < 1e-12);
                assert!((j.mem_gb - 29.0).abs() < 1e-12);
                assert_eq!((j.start, j.end), (1000, 2000));
            }
            other => panic!("unexpected {other:?}"),
        }
        // Non-terminated rows are filtered, not errors.
        let row = cells("j2,tf,1,Running,1000,,600,29.0,50,V100");
        assert_eq!(
            TraceFormat::Alibaba.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredStatus
        );
        // CPU-only tasks (empty or zero plan_gpu — common in the real
        // dump) are their own filter category, not corruption.
        let row = cells("jc,tf,1,Terminated,1000,2000,600,29.0,,V100");
        assert_eq!(
            TraceFormat::Alibaba.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredNoGpu
        );
        let row = cells("jz,tf,1,Terminated,1000,2000,600,29.0,0,V100");
        assert_eq!(
            TraceFormat::Alibaba.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredNoGpu
        );
        // inst_num expands the row (as a count, not clones).
        let row = cells("j3,tf,3,Terminated,5,10,1,1,25,");
        match TraceFormat::Alibaba.parse_row(&header, &row).unwrap() {
            RowOutcome::Jobs(_, count) => assert_eq!(count, 3),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn alibaba_row_errors_are_local() {
        let header = Header::parse(
            "job_name,inst_num,status,start_time,end_time,plan_gpu",
            &ALIBABA_REQUIRED,
        )
        .unwrap();
        for bad in [
            "j,1,Terminated,,2000,50",         // missing start
            "j,1,Terminated,1000,2000,much",   // non-numeric share
            "j,1,Terminated,never,2000,50",    // bad timestamp
            ",1,Terminated,1000,2000,50",      // missing key
            "j,1e12,Terminated,1000,2000,50",  // inst_num balloon
            "j,0,Terminated,1000,2000,50",     // inst_num below 1
        ] {
            assert!(
                TraceFormat::Alibaba.parse_row(&header, &cells(bad)).is_err(),
                "{bad}"
            );
        }
    }

    #[test]
    fn philly_row_parses_all_final_statuses() {
        let header = Header::parse(
            "jobid,vc,status,submitted_time,start_time,finished_time,num_gpus,mem_gb",
            &PHILLY_REQUIRED,
        )
        .unwrap();
        for status in ["Pass", "Killed", "Failed"] {
            let row = cells(&format!(
                "app_123,vc1,{status},2017-10-03 11:00:00,2017-10-03 11:22:43,2017-10-03 12:22:43,1,16"
            ));
            match TraceFormat::Philly.parse_row(&header, &row).unwrap() {
                RowOutcome::Jobs(j, count) => {
                    assert_eq!(count, 1);
                    assert_eq!(j.start, 1_507_029_763);
                    assert_eq!(j.end - j.start, 3600);
                    // Single device + explicit memory → memory-sized.
                    assert_eq!(j.gpu_share, 0.0);
                    assert!((j.mem_gb - 16.0).abs() < 1e-12);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // No memory request → the full device it asked for.
        let row = cells("b,vc1,Pass,x,2017-10-03 11:22:43,2017-10-03 12:22:43,1,");
        match TraceFormat::Philly.parse_row(&header, &row).unwrap() {
            RowOutcome::Jobs(j, _) => assert!((j.gpu_share - 1.0).abs() < 1e-12),
            other => panic!("unexpected {other:?}"),
        }
        // Multi-device jobs expand into one full-GPU workload per device
        // (their memory request is per-job, so it is dropped — each
        // device is fully pinned anyway).
        let row = cells("c,vc1,Pass,x,2017-10-03 11:22:43,2017-10-03 12:22:43,4,16");
        match TraceFormat::Philly.parse_row(&header, &row).unwrap() {
            RowOutcome::Jobs(j, count) => {
                assert_eq!(count, 4);
                assert!((j.gpu_share - 1.0).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Unknown status → filtered.
        let row = cells("a,vc1,Queued,x,2017-10-03 11:22:43,2017-10-03 12:22:43,1,16");
        assert_eq!(
            TraceFormat::Philly.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredStatus
        );
        // Killed before ever starting (empty start/finish, as in the real
        // log) → filtered, never a parse error.
        let row = cells("d,vc1,Killed,2017-10-03 10:00:00,,,1,16");
        assert_eq!(
            TraceFormat::Philly.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredStatus
        );
        // A zero device count is a CPU row → its own filter category.
        let row = cells("g,vc1,Pass,x,2017-10-03 11:22:43,2017-10-03 12:22:43,0,");
        assert_eq!(
            TraceFormat::Philly.parse_row(&header, &row).unwrap(),
            RowOutcome::FilteredNoGpu
        );
        // But a row TRUNCATED before the timestamp cells is malformed.
        let row = cells("t1,vc1,Pass");
        assert!(TraceFormat::Philly.parse_row(&header, &row).is_err());
        // And garbage non-empty timestamps stay malformed.
        let row = cells("e,vc1,Pass,x,not-a-time,2017-10-03 12:22:43,1,16");
        assert!(TraceFormat::Philly.parse_row(&header, &row).is_err());
        // A negative device count is malformed even with a memory request
        // (the share transform must not fold it into a valid 0.0).
        let row = cells("f,vc1,Pass,x,2017-10-03 11:22:43,2017-10-03 12:22:43,-4,16");
        assert!(TraceFormat::Philly.parse_row(&header, &row).is_err());
        // So is a fractional multi-device count (would drop demand).
        let row = cells("h,vc1,Pass,x,2017-10-03 11:22:43,2017-10-03 12:22:43,1.5,");
        assert!(TraceFormat::Philly.parse_row(&header, &row).is_err());
    }

    #[test]
    fn header_missing_required_column() {
        assert!(Header::parse("job_name,status", &ALIBABA_REQUIRED).is_err());
        assert!(Header::parse("jobid,status,start_time,finished_time,num_gpus", &PHILLY_REQUIRED).is_ok());
    }

    #[test]
    fn tenant_hash_is_stable() {
        assert_eq!(tenant_hash("vc1"), tenant_hash("vc1"));
        assert_ne!(tenant_hash("vc1"), tenant_hash("vc2"));
    }
}
