//! Wall-clock → scheduling-slot normalization.
//!
//! Raw jobs carry epoch-second start/end times; the simulator runs on
//! integer slots. Normalization anchors the earliest start at slot 0,
//! divides wall time by a configurable slot width, derives lifespans from
//! `end - start` (rounded up, floor one slot, optionally capped) and
//! assigns workload ids in canonical arrival order — so the resulting
//! [`Trace`] is independent of row order in the source file.

use super::formats::RawJob;
use super::report::IngestReport;
use crate::mig::Profile;
use crate::workload::spec::{TenantId, Workload, WorkloadId};
use crate::workload::trace::Trace;

/// Normalization parameters.
#[derive(Clone, Debug)]
pub struct NormalizeConfig {
    /// Slot width in wall-clock seconds (default 300 = five minutes, a
    /// slot granularity at which both public traces keep sub-hour jobs
    /// visible without exploding the horizon).
    pub slot_secs: u64,
    /// Lifespan cap in slots; 0 = uncapped. Long-tail jobs (days) otherwise
    /// pin slices for the entire replay.
    pub max_duration_slots: u64,
}

impl Default for NormalizeConfig {
    fn default() -> Self {
        Self { slot_secs: 300, max_duration_slots: 0 }
    }
}

/// A raw job whose request has already been mapped to a profile.
#[derive(Clone, Debug)]
pub struct MappedJob {
    pub profile: Profile,
    pub tenant: u32,
    pub start: u64,
    pub end: u64,
}

/// Normalize mapped jobs into trace workloads, updating the report's
/// duration counters. Jobs with `end < start` must be filtered out by the
/// caller (they are row errors, not normalization input).
pub fn normalize(
    jobs: &[MappedJob],
    config: &NormalizeConfig,
    report: &mut IngestReport,
) -> Vec<Workload> {
    assert!(config.slot_secs > 0, "slot width must be positive");
    if jobs.is_empty() {
        return Vec::new();
    }
    let t0 = jobs.iter().map(|j| j.start).min().unwrap();

    // Sort by a TOTAL key — (start, end, profile, tenant) — and assign ids
    // post-sort: the output trace is then canonical under any source row
    // order, including ties on start time (same-second submissions are
    // common in real logs). Jobs identical in every key field are
    // interchangeable, so the residual stable tie-break cannot change
    // the rendered trace.
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by_key(|&i| {
        let j = &jobs[i];
        (j.start, j.end, j.profile.index(), j.tenant)
    });

    let mut out = Vec::with_capacity(jobs.len());
    for (id, &i) in order.iter().enumerate() {
        let j = &jobs[i];
        debug_assert!(j.end >= j.start, "caller must filter end < start");
        let arrival_slot = (j.start - t0) / config.slot_secs;
        let dur_secs = j.end - j.start;
        if dur_secs == 0 {
            report.zero_duration += 1;
        }
        // Ceil-divide, floor one slot: a job always occupies the slot it
        // arrived in.
        let mut duration_slots = dur_secs.div_ceil(config.slot_secs).max(1);
        if config.max_duration_slots > 0 && duration_slots > config.max_duration_slots {
            duration_slots = config.max_duration_slots;
            report.clamped_duration += 1;
        }
        out.push(Workload {
            id: WorkloadId(id as u64),
            tenant: TenantId(j.tenant),
            profile: j.profile,
            arrival_slot,
            duration_slots,
        });
    }
    out
}

/// Assemble the canonical trace from normalized workloads.
pub fn build_trace(
    description: &str,
    capacity_slices: u64,
    workloads: &[Workload],
) -> Trace {
    Trace::from_workloads(description, capacity_slices, workloads)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(start: u64, end: u64) -> MappedJob {
        MappedJob { profile: Profile::P1g10gb, tenant: 0, start, end }
    }

    #[test]
    fn anchors_sorts_and_assigns_ids() {
        let jobs = vec![job(1000, 1600), job(400, 700), job(700, 701)];
        let mut report = IngestReport::new("t", "alibaba");
        let ws = normalize(&jobs, &NormalizeConfig { slot_secs: 300, max_duration_slots: 0 }, &mut report);
        // Sorted by start: 400, 700, 1000 → slots 0, 1, 2.
        assert_eq!(ws.len(), 3);
        assert_eq!(ws[0].id, WorkloadId(0));
        assert_eq!(ws[0].arrival_slot, 0);
        assert_eq!(ws[0].duration_slots, 1); // 300 s exactly → 1 slot
        assert_eq!(ws[1].arrival_slot, 1);
        assert_eq!(ws[1].duration_slots, 1); // 1 s rounds up
        assert_eq!(ws[2].arrival_slot, 2);
        assert_eq!(ws[2].duration_slots, 2); // 600 s → 2 slots
    }

    #[test]
    fn zero_duration_raised_and_counted() {
        let jobs = vec![job(50, 50)];
        let mut report = IngestReport::new("t", "alibaba");
        let ws = normalize(&jobs, &NormalizeConfig::default(), &mut report);
        assert_eq!(ws[0].duration_slots, 1);
        assert_eq!(report.zero_duration, 1);
    }

    #[test]
    fn duration_cap_applies_and_counts() {
        let jobs = vec![job(0, 1_000_000)];
        let mut report = IngestReport::new("t", "alibaba");
        let cfg = NormalizeConfig { slot_secs: 300, max_duration_slots: 10 };
        let ws = normalize(&jobs, &cfg, &mut report);
        assert_eq!(ws[0].duration_slots, 10);
        assert_eq!(report.clamped_duration, 1);
    }

    #[test]
    fn out_of_order_input_yields_identical_trace() {
        let a = vec![job(10, 400), job(5000, 5600), job(900, 1000)];
        let mut b = a.clone();
        b.reverse();
        let mut ra = IngestReport::new("a", "x");
        let mut rb = IngestReport::new("b", "x");
        let cfg = NormalizeConfig::default();
        let wa = normalize(&a, &cfg, &mut ra);
        let wb = normalize(&b, &cfg, &mut rb);
        assert_eq!(wa, wb);
        let ta = build_trace("t", 80, &wa);
        let tb = build_trace("t", 80, &wb);
        assert_eq!(ta.render_jsonl(), tb.render_jsonl());
    }

    #[test]
    fn equal_start_times_still_canonicalize() {
        // Same-second submissions with different shapes: swapping the
        // source rows must not change which id carries which profile.
        let a = vec![
            MappedJob { profile: Profile::P3g40gb, tenant: 7, start: 100, end: 700 },
            MappedJob { profile: Profile::P1g10gb, tenant: 3, start: 100, end: 400 },
        ];
        let b: Vec<MappedJob> = a.iter().rev().cloned().collect();
        let mut ra = IngestReport::new("a", "x");
        let mut rb = IngestReport::new("b", "x");
        let cfg = NormalizeConfig::default();
        let wa = normalize(&a, &cfg, &mut ra);
        let wb = normalize(&b, &cfg, &mut rb);
        assert_eq!(wa, wb);
        assert_eq!(
            build_trace("t", 80, &wa).render_jsonl(),
            build_trace("t", 80, &wb).render_jsonl()
        );
    }

    #[test]
    fn empty_input_is_empty_output() {
        let mut report = IngestReport::new("t", "philly");
        assert!(normalize(&[], &NormalizeConfig::default(), &mut report).is_empty());
    }
}
