//! Per-file ingestion accounting: what was imported, what was skipped and
//! why — so a lossy import is visible, never silent.

use crate::util::json::Json;
use crate::util::table::Table;

/// Cap on the number of row-level errors kept verbatim (the counters keep
/// counting past it; detail on a million-row corrupt file is useless).
pub const MAX_ERROR_DETAIL: usize = 32;

/// One row-local problem: physical line number (1-based, header = line of
/// its own) and the reason.
#[derive(Clone, Debug, PartialEq)]
pub struct RowError {
    pub line: usize,
    pub reason: String,
}

/// The error report accompanying every ingested trace.
#[derive(Clone, Debug, Default)]
pub struct IngestReport {
    /// Source label (path or caller-provided name).
    pub source: String,
    /// Format name (`alibaba` / `philly`).
    pub format: String,
    /// Data rows seen (excluding the header and blank lines).
    pub rows_total: u64,
    /// Workloads emitted into the trace (≥ rows can differ via `inst_num`
    /// expansion or skips).
    pub imported: u64,
    /// Rows dropped as malformed (CSV/quoting/field errors) — detailed in
    /// `errors` up to [`MAX_ERROR_DETAIL`].
    pub skipped_malformed: u64,
    /// Rows dropped by the status filter (e.g. Alibaba non-`Terminated`,
    /// Philly never-started).
    pub filtered_status: u64,
    /// Rows dropped for requesting no GPU (CPU-only tasks).
    pub filtered_no_gpu: u64,
    /// Rows rejected by the strict mapping policy (unmappable requests).
    pub unmappable: u64,
    /// Workloads whose request exceeded the largest profile and was
    /// clamped to it (nearest-up policy).
    pub clamped_profile: u64,
    /// Workloads with `end == start` whose lifespan was raised to 1 slot.
    pub zero_duration: u64,
    /// Workloads whose lifespan hit the configured cap.
    pub clamped_duration: u64,
    /// Row-level detail (capped; `skipped_malformed + unmappable` is the
    /// true total).
    pub errors: Vec<RowError>,
}

impl IngestReport {
    pub fn new(source: &str, format: &str) -> Self {
        Self { source: source.to_string(), format: format.to_string(), ..Self::default() }
    }

    /// Record a row-level error, keeping detail up to the cap.
    pub fn push_error(&mut self, line: usize, reason: String) {
        if self.errors.len() < MAX_ERROR_DETAIL {
            self.errors.push(RowError { line, reason });
        }
    }

    /// Rows that contributed workloads / total data rows (1.0 for clean
    /// files and empty files alike — an empty file loses nothing).
    pub fn ok_fraction(&self) -> f64 {
        if self.rows_total == 0 {
            return 1.0;
        }
        let dropped = self.skipped_malformed + self.unmappable;
        1.0 - dropped as f64 / self.rows_total as f64
    }

    pub fn to_json(&self) -> Json {
        let errors: Vec<Json> = self
            .errors
            .iter()
            .map(|e| Json::obj().with("line", e.line).with("reason", e.reason.as_str()))
            .collect();
        Json::obj()
            .with("source", self.source.as_str())
            .with("format", self.format.as_str())
            .with("rows_total", self.rows_total)
            .with("imported", self.imported)
            .with("skipped_malformed", self.skipped_malformed)
            .with("filtered_status", self.filtered_status)
            .with("filtered_no_gpu", self.filtered_no_gpu)
            .with("unmappable", self.unmappable)
            .with("clamped_profile", self.clamped_profile)
            .with("zero_duration", self.zero_duration)
            .with("clamped_duration", self.clamped_duration)
            .with("ok_fraction", self.ok_fraction())
            .with("errors", Json::Arr(errors))
    }

    /// Human-readable summary table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["counter", "value"])
            .title(&format!("ingest report — {} ({})", self.source, self.format));
        let rows: [(&str, u64); 9] = [
            ("data rows", self.rows_total),
            ("workloads imported", self.imported),
            ("skipped (malformed)", self.skipped_malformed),
            ("filtered (status)", self.filtered_status),
            ("filtered (no GPU requested)", self.filtered_no_gpu),
            ("unmappable (strict)", self.unmappable),
            ("clamped to largest profile", self.clamped_profile),
            ("zero-duration (raised to 1 slot)", self.zero_duration),
            ("duration clamped to cap", self.clamped_duration),
        ];
        for (label, value) in rows {
            t.row(&[label.to_string(), value.to_string()]);
        }
        let mut out = t.render();
        if !self.errors.is_empty() {
            out.push_str(&format!(
                "first {} error(s) of {}:\n",
                self.errors.len(),
                self.skipped_malformed + self.unmappable
            ));
            for e in &self.errors {
                out.push_str(&format!("  line {}: {}\n", e.line, e.reason));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_detail_is_capped_but_counts_run_on() {
        let mut r = IngestReport::new("t.csv", "alibaba");
        for i in 0..100 {
            r.skipped_malformed += 1;
            r.push_error(i + 2, format!("boom {i}"));
        }
        assert_eq!(r.errors.len(), MAX_ERROR_DETAIL);
        assert_eq!(r.skipped_malformed, 100);
        let j = r.to_json();
        assert_eq!(j.req_u64("skipped_malformed").unwrap(), 100);
        assert_eq!(j.get("errors").unwrap().as_arr().unwrap().len(), MAX_ERROR_DETAIL);
    }

    #[test]
    fn ok_fraction_edges() {
        let mut r = IngestReport::new("x", "philly");
        assert_eq!(r.ok_fraction(), 1.0); // empty file
        r.rows_total = 10;
        r.skipped_malformed = 2;
        r.unmappable = 3;
        assert!((r.ok_fraction() - 0.5).abs() < 1e-12);
        assert!(r.render().contains("skipped (malformed)"));
    }
}
