//! Mapping raw resource requests onto MIG profiles.
//!
//! Public GPU-cluster traces describe demand as a *fractional GPU share*
//! (Alibaba `plan_gpu`, in percent of one GPU) or a *device count* (Philly
//! `num_gpus`), optionally with a memory request in GB. MIG offers neither:
//! a workload gets one of the Table I profiles. The [`ProfileMapper`]
//! bridges the two worlds with an explicit, configurable policy so the
//! mapping — the one modelling judgment call in trace ingestion — is never
//! implicit.
//!
//! A request needs `ceil(share × 7)` compute slices (a full GPU exposes 7
//! compute slices) and `ceil(mem_gb / mem_per_slice)` memory slices (8 per
//! GPU). The **nearest-fit-up** policy picks the smallest enabled profile
//! satisfying both, clamping oversize requests (multi-GPU shares, >1-GPU
//! memory) to the largest enabled profile; the **strict** policy rejects
//! any request that does not fit a profile exactly as unmappable.

use crate::mig::{HardwareModel, Profile};

/// How to resolve requests that fall outside the profile lattice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MappingPolicy {
    /// Round up to the smallest profile that satisfies the request; clamp
    /// oversize requests to the largest enabled profile (flagged in the
    /// [`MapOutcome`] and counted by the ingest report).
    NearestUp,
    /// Reject rows whose request exceeds every enabled profile.
    Strict,
}

impl MappingPolicy {
    pub fn name(self) -> &'static str {
        match self {
            MappingPolicy::NearestUp => "nearest-up",
            MappingPolicy::Strict => "strict",
        }
    }

    pub fn parse(s: &str) -> Option<MappingPolicy> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "nearest-up" | "nearest" | "up" => Some(MappingPolicy::NearestUp),
            "strict" => Some(MappingPolicy::Strict),
            _ => None,
        }
    }
}

/// A successful mapping; `clamped` marks requests that exceeded the
/// largest enabled profile and were rounded *down* to it (nearest-up
/// policy only — strict rejects these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MapOutcome {
    pub profile: Profile,
    pub clamped: bool,
}

/// Why a request failed to map — the ingest report counts the two cases
/// separately (garbage input vs a policy decision).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MapError {
    /// Nonsensical input (negative / non-finite numbers).
    Invalid(String),
    /// A well-formed request larger than every enabled profile, rejected
    /// by [`MappingPolicy::Strict`].
    Unmappable(String),
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::Invalid(m) | MapError::Unmappable(m) => f.write_str(m),
        }
    }
}

/// Compute slices exposed by a full GPU (the 7g in `7g.80gb`).
const FULL_GPU_COMPUTE: f64 = 7.0;

/// Maps (gpu share, memory GB) requests onto MIG profiles.
///
/// The target's slice geometry is an explicit part of the mapper state:
/// the same `mem_gb` request lands on *different* profiles depending on
/// the device class it is mapped against (16 GB is 4 slices of an
/// A100-40GB but a single slice of an H200), so on a heterogeneous fleet
/// ingestion must build one mapper per target class, never share one
/// across classes.
#[derive(Clone, Debug)]
pub struct ProfileMapper {
    hardware: HardwareModel,
    policy: MappingPolicy,
    /// GB per memory slice on the target class (`total_memory_gb / 8`),
    /// frozen at construction — the quantity that varies across a fleet.
    mem_per_slice_gb: f64,
}

impl ProfileMapper {
    pub fn new(hardware: HardwareModel, policy: MappingPolicy) -> Self {
        let mem_per_slice_gb =
            f64::from(hardware.total_memory_gb()) / hardware.num_slices() as f64;
        Self { hardware, policy, mem_per_slice_gb }
    }

    pub fn policy(&self) -> MappingPolicy {
        self.policy
    }

    pub fn hardware(&self) -> &HardwareModel {
        &self.hardware
    }

    /// The target class's memory-slice granularity in GB (10 for
    /// A100-80GB/H100, 5 for A100-40GB, 18 for H200).
    pub fn mem_per_slice_gb(&self) -> f64 {
        self.mem_per_slice_gb
    }

    /// Map a request to a profile. `gpu_share` is the fraction of one GPU
    /// (1.0 = a full device; Philly's `num_gpus = 4` arrives as 4.0),
    /// `mem_gb` the requested GPU memory (0 = unconstrained).
    ///
    /// Errors are descriptive strings: non-finite/negative inputs are
    /// invalid under every policy; requests exceeding the largest enabled
    /// profile are unmappable under [`MappingPolicy::Strict`].
    pub fn map(&self, gpu_share: f64, mem_gb: f64) -> Result<MapOutcome, MapError> {
        if !gpu_share.is_finite() || gpu_share < 0.0 {
            return Err(MapError::Invalid(format!("invalid gpu share {gpu_share}")));
        }
        if !mem_gb.is_finite() || mem_gb < 0.0 {
            return Err(MapError::Invalid(format!("invalid memory request {mem_gb} GB")));
        }
        // Slice demand implied by the request. A zero share is a CPU-only
        // row that slipped through the format filter — give it the smallest
        // footprint rather than inventing a rejection.
        let need_compute = ((gpu_share * FULL_GPU_COMPUTE).ceil() as u32).max(1);
        let need_mem_slices = (mem_gb / self.mem_per_slice_gb).ceil() as u32;

        // Smallest enabled profile satisfying both demands: profiles() is
        // Table I order (largest first), so take the LAST fitting one —
        // ties on memory slices resolve to the fewest compute slices
        // (3g.40gb preferred over 4g.40gb for a 3-compute request).
        let fit = self
            .hardware
            .profiles()
            .filter(|p| {
                u32::from(p.compute_slices()) >= need_compute
                    && u32::from(p.size()) >= need_mem_slices
            })
            .last();
        if let Some(profile) = fit {
            return Ok(MapOutcome { profile, clamped: false });
        }

        // Nothing fits: the request is larger than the largest enabled
        // profile (multi-GPU share, or memory beyond one device).
        match self.policy {
            MappingPolicy::Strict => Err(MapError::Unmappable(format!(
                "unmappable request (share {gpu_share:.2} → {need_compute} compute \
                 slices, {mem_gb:.0} GB → {need_mem_slices} memory slices) under \
                 the strict policy"
            ))),
            MappingPolicy::NearestUp => {
                // Largest enabled profile = first in Table I order.
                let largest = self.hardware.profiles().next().ok_or_else(|| {
                    MapError::Invalid("hardware model has no enabled profiles".into())
                })?;
                Ok(MapOutcome { profile: largest, clamped: true })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mapper(policy: MappingPolicy) -> ProfileMapper {
        ProfileMapper::new(HardwareModel::a100_80gb(), policy)
    }

    #[test]
    fn exact_and_nearest_up_shares() {
        let m = mapper(MappingPolicy::NearestUp);
        // share → ceil(share*7) compute slices → smallest fitting profile.
        let cases = [
            (0.0, Profile::P1g10gb),
            (0.10, Profile::P1g10gb),  // 1 compute slice
            (0.25, Profile::P2g20gb),  // 2
            (0.40, Profile::P3g40gb),  // 3
            (0.50, Profile::P4g40gb),  // 4
            (0.70, Profile::P7g80gb),  // 5 — only the full GPU has ≥5
            (1.0, Profile::P7g80gb),
        ];
        for (share, want) in cases {
            let got = m.map(share, 0.0).unwrap();
            assert_eq!(got.profile, want, "share {share}");
            assert!(!got.clamped, "share {share}");
        }
    }

    #[test]
    fn memory_constraint_raises_the_floor() {
        let m = mapper(MappingPolicy::NearestUp);
        // 1 compute slice but 15 GB → needs 2 memory slices → 1g.20gb.
        assert_eq!(m.map(0.1, 15.0).unwrap().profile, Profile::P1g20gb);
        // 25 GB → 3 memory slices → smallest with size ≥ 3 is 3g.40gb.
        assert_eq!(m.map(0.1, 25.0).unwrap().profile, Profile::P3g40gb);
        // 45 GB → 5 memory slices → only the full GPU.
        assert_eq!(m.map(0.1, 45.0).unwrap().profile, Profile::P7g80gb);
    }

    #[test]
    fn compute_tie_prefers_fewer_compute_slices() {
        // 3 compute slices fits both 3g.40gb and 4g.40gb (same memory
        // footprint); nearest-up picks 3g.40gb.
        let m = mapper(MappingPolicy::NearestUp);
        assert_eq!(m.map(3.0 / 7.0, 0.0).unwrap().profile, Profile::P3g40gb);
    }

    #[test]
    fn oversize_clamps_under_nearest_up() {
        let m = mapper(MappingPolicy::NearestUp);
        let got = m.map(2.0, 0.0).unwrap(); // two full GPUs
        assert_eq!(got.profile, Profile::P7g80gb);
        assert!(got.clamped);
        let got = m.map(0.1, 200.0).unwrap(); // > 80 GB memory
        assert_eq!(got.profile, Profile::P7g80gb);
        assert!(got.clamped);
    }

    #[test]
    fn oversize_rejects_under_strict() {
        let m = mapper(MappingPolicy::Strict);
        assert!(matches!(m.map(2.0, 0.0), Err(MapError::Unmappable(_))));
        assert!(matches!(m.map(0.1, 200.0), Err(MapError::Unmappable(_))));
        // In-lattice requests still map.
        assert_eq!(m.map(1.0, 80.0).unwrap().profile, Profile::P7g80gb);
    }

    #[test]
    fn invalid_inputs_error_under_both_policies() {
        for policy in [MappingPolicy::NearestUp, MappingPolicy::Strict] {
            let m = mapper(policy);
            assert!(matches!(m.map(-0.5, 0.0), Err(MapError::Invalid(_))));
            assert!(matches!(m.map(f64::NAN, 0.0), Err(MapError::Invalid(_))));
            assert!(matches!(m.map(0.5, f64::INFINITY), Err(MapError::Invalid(_))));
        }
    }

    #[test]
    fn restricted_hardware_changes_the_lattice() {
        let hw = HardwareModel::a100_80gb()
            .with_profiles(&[Profile::P3g40gb, Profile::P1g10gb]);
        let m = ProfileMapper::new(hw, MappingPolicy::NearestUp);
        // 2 compute slices: 2g.20gb is disabled → next fit is 3g.40gb.
        assert_eq!(m.map(0.25, 0.0).unwrap().profile, Profile::P3g40gb);
        // 5 compute slices: nothing fits → clamp to largest enabled.
        let got = m.map(0.7, 0.0).unwrap();
        assert_eq!(got.profile, Profile::P3g40gb);
        assert!(got.clamped);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [MappingPolicy::NearestUp, MappingPolicy::Strict] {
            assert_eq!(MappingPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(MappingPolicy::parse("NEAREST_UP"), Some(MappingPolicy::NearestUp));
        assert_eq!(MappingPolicy::parse("fuzzy"), None);
    }

    #[test]
    fn a100_40gb_memory_slices_are_5gb() {
        let m = ProfileMapper::new(HardwareModel::a100_40gb(), MappingPolicy::NearestUp);
        // 8 GB on a 5 GB/slice part → 2 memory slices → 1g.20gb shape.
        assert_eq!(m.map(0.1, 8.0).unwrap().profile, Profile::P1g20gb);
    }

    #[test]
    fn same_request_maps_per_target_class_geometry() {
        // The heterogeneous-fleet contract: one mapper per target class.
        // A 16 GB request is 4 slices of an A100-40GB (→ 3g.40gb shape,
        // the smallest profile with size ≥ 4) but a single slice of an
        // H200 (→ 1g.10gb shape). Sharing one mapper across classes would
        // silently over- or under-provision one of them.
        let a40 = ProfileMapper::new(HardwareModel::a100_40gb(), MappingPolicy::NearestUp);
        let h200 = ProfileMapper::new(HardwareModel::h200_141gb(), MappingPolicy::NearestUp);
        assert_eq!(a40.mem_per_slice_gb(), 5.0);
        assert_eq!(h200.mem_per_slice_gb(), 18.0);
        let on_a40 = a40.map(0.1, 16.0).unwrap();
        let on_h200 = h200.map(0.1, 16.0).unwrap();
        assert_eq!(on_a40.profile, Profile::P3g40gb);
        assert_eq!(on_h200.profile, Profile::P1g10gb);
        assert!(!on_a40.clamped && !on_h200.clamped);
        // And clamping thresholds differ too: 50 GB overflows the 40 GB
        // part but fits comfortably on the H200.
        assert!(a40.map(0.1, 50.0).unwrap().clamped);
        assert!(!h200.map(0.1, 50.0).unwrap().clamped);
    }
}
