//! Prometheus text exposition (format version 0.0.4): `# HELP` / `# TYPE`
//! headers, label escaping, and cumulative `_bucket` rendering for
//! [`HistSnapshot`]s.
//!
//! Families are emitted in the exact order the caller registers them, so
//! a given server state always serializes identically (deterministic
//! ordering is what lets tests pin the output and diffs stay readable).
//! Duplicate family names are a programming error and panic in debug
//! builds.

use std::fmt::Write as _;

use super::hist::HistSnapshot;

/// The `Content-Type` of the rendered exposition.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Escape a label value: backslash, double quote and newline, per the
/// exposition-format spec.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// An ordered label set, rendered once at construction. Cloning is cheap
/// (one `String`), which the histogram renderer uses to splice `le` in.
#[derive(Clone, Debug, Default)]
pub struct Labels(String);

impl Labels {
    pub fn new() -> Self {
        Self(String::new())
    }

    /// Append one `key="value"` pair (escaped); builder style.
    pub fn with(mut self, key: &str, value: &str) -> Self {
        if !self.0.is_empty() {
            self.0.push(',');
        }
        self.0.push_str(key);
        self.0.push_str("=\"");
        self.0.push_str(&escape_label(value));
        self.0.push('"');
        self
    }

    /// Append the braced label set (with an optional extra pair spliced
    /// in) directly onto an output buffer — the renderer is called per
    /// scrape per sample, so it must not allocate.
    fn write_rendered(&self, out: &mut String, extra: Option<&str>) {
        match (self.0.is_empty(), extra) {
            (true, None) => {}
            (true, Some(e)) => {
                out.push('{');
                out.push_str(e);
                out.push('}');
            }
            (false, None) => {
                out.push('{');
                out.push_str(&self.0);
                out.push('}');
            }
            (false, Some(e)) => {
                out.push('{');
                out.push_str(&self.0);
                out.push(',');
                out.push_str(e);
                out.push('}');
            }
        }
    }
}

/// Format a sample value the way Prometheus expects: integral values
/// without a fractional part, everything else via shortest-round-trip
/// `Display` (rust never emits scientific notation there).
fn fmt_value(v: f64) -> String {
    let mut out = String::new();
    write_value(&mut out, v);
    out
}

/// [`fmt_value`], appended into a caller-owned buffer.
fn write_value(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

/// The exposition builder: register families in output order, then
/// [`Expo::finish`].
#[derive(Debug, Default)]
pub struct Expo {
    out: String,
    families: Vec<String>,
}

impl Expo {
    pub fn new() -> Self {
        Self::default()
    }

    /// An exposition that reuses `buf`'s allocation (cleared first). The
    /// `/metrics` handler threads one scratch `String` per thread
    /// through here so steady-state scrapes render without growing the
    /// heap.
    pub fn with_buffer(mut buf: String) -> Self {
        buf.clear();
        Self { out: buf, families: Vec::new() }
    }

    fn family(&mut self, name: &str, kind: &str, help: &str) {
        if cfg!(debug_assertions) {
            assert!(
                !self.families.iter().any(|f| f == name),
                "duplicate metric family {name}"
            );
            self.families.push(name.to_string());
        }
        let _ = write!(self.out, "# HELP {name} {help}\n# TYPE {name} {kind}\n");
    }

    /// A counter family with one sample per label set.
    pub fn counter(&mut self, name: &str, help: &str, samples: &[(Labels, u64)]) {
        self.family(name, "counter", help);
        for (labels, v) in samples {
            self.out.push_str(name);
            labels.write_rendered(&mut self.out, None);
            let _ = writeln!(self.out, " {v}");
        }
    }

    /// A gauge family with one sample per label set.
    pub fn gauge(&mut self, name: &str, help: &str, samples: &[(Labels, f64)]) {
        self.family(name, "gauge", help);
        for (labels, v) in samples {
            self.out.push_str(name);
            labels.write_rendered(&mut self.out, None);
            self.out.push(' ');
            write_value(&mut self.out, *v);
            self.out.push('\n');
        }
    }

    /// A histogram family: cumulative `_bucket` series per finite bound,
    /// the `le="+Inf"` bucket (== `_count` by snapshot construction), then
    /// `_sum` and `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, samples: &[(Labels, HistSnapshot)]) {
        self.family(name, "histogram", help);
        let mut le = String::with_capacity(32);
        for (labels, snap) in samples {
            let cum = snap.cumulative();
            for (i, &bound) in snap.bounds.iter().enumerate() {
                le.clear();
                le.push_str("le=\"");
                write_value(&mut le, bound);
                le.push('"');
                self.out.push_str(name);
                self.out.push_str("_bucket");
                labels.write_rendered(&mut self.out, Some(&le));
                let _ = writeln!(self.out, " {}", cum[i]);
            }
            let count = *cum.last().unwrap_or(&0);
            self.out.push_str(name);
            self.out.push_str("_bucket");
            labels.write_rendered(&mut self.out, Some("le=\"+Inf\""));
            let _ = writeln!(self.out, " {count}");
            self.out.push_str(name);
            self.out.push_str("_sum");
            labels.write_rendered(&mut self.out, None);
            self.out.push(' ');
            write_value(&mut self.out, snap.sum);
            self.out.push('\n');
            self.out.push_str(name);
            self.out.push_str("_count");
            labels.write_rendered(&mut self.out, None);
            let _ = writeln!(self.out, " {count}");
        }
    }

    /// The rendered exposition text.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::LatencyHist;

    #[test]
    fn label_escaping_covers_the_spec_set() {
        assert_eq!(escape_label(r#"a\b"c"#), r#"a\\b\"c"#);
        assert_eq!(escape_label("line\nbreak"), r"line\nbreak");
        assert_eq!(escape_label("plain"), "plain");
    }

    #[test]
    fn counter_and_gauge_render_with_headers() {
        let mut e = Expo::new();
        e.counter(
            "migsched_test_total",
            "A test counter.",
            &[
                (Labels::new().with("shard", "0"), 3),
                (Labels::new().with("shard", "1"), 4),
            ],
        );
        e.gauge("migsched_test_ratio", "A test gauge.", &[(Labels::new(), 0.25)]);
        let text = e.finish();
        assert!(text.contains("# TYPE migsched_test_total counter\n"));
        assert!(text.contains("migsched_test_total{shard=\"0\"} 3\n"));
        assert!(text.contains("migsched_test_total{shard=\"1\"} 4\n"));
        assert!(text.contains("# HELP migsched_test_ratio A test gauge.\n"));
        assert!(text.contains("migsched_test_ratio 0.25\n"));
        // Integral gauges render without a fractional part.
        assert_eq!(fmt_value(7.0), "7");
        assert_eq!(fmt_value(-2.0), "-2");
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_inf_matches_count() {
        let h = LatencyHist::new();
        h.record_ns(500);
        h.record_ns(1_500);
        h.record_ns(3_000_000);
        let mut e = Expo::new();
        e.histogram(
            "migsched_test_seconds",
            "A test histogram.",
            &[(Labels::new().with("endpoint", "/v1/workloads"), h.snapshot())],
        );
        let text = e.finish();
        assert!(text.contains("# TYPE migsched_test_seconds histogram\n"));
        // First bound is 1µs; the 500ns observation is inside it.
        assert!(text.contains(
            "migsched_test_seconds_bucket{endpoint=\"/v1/workloads\",le=\"0.000001\"} 1\n"
        ));
        assert!(text.contains(
            "migsched_test_seconds_bucket{endpoint=\"/v1/workloads\",le=\"+Inf\"} 3\n"
        ));
        assert!(text.contains("migsched_test_seconds_count{endpoint=\"/v1/workloads\"} 3\n"));
        // Cumulative counts never decrease along the bucket series.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
    }

    #[test]
    fn deterministic_ordering_follows_registration() {
        let build = || {
            let mut e = Expo::new();
            e.counter("b_total", "b", &[(Labels::new(), 1)]);
            e.counter("a_total", "a", &[(Labels::new(), 2)]);
            e.finish()
        };
        assert_eq!(build(), build());
        let text = build();
        let b = text.find("# TYPE b_total").unwrap();
        let a = text.find("# TYPE a_total").unwrap();
        assert!(b < a, "families serialize in registration order");
    }

    #[test]
    fn with_buffer_renders_identically_and_keeps_capacity() {
        let render = |mut e: Expo| {
            let h = LatencyHist::new();
            h.record_ns(2_000);
            e.counter("x_total", "x", &[(Labels::new().with("shard", "0"), 3)]);
            e.gauge("x_ratio", "r", &[(Labels::new(), 0.5)]);
            e.histogram("x_seconds", "h", &[(Labels::new(), h.snapshot())]);
            e.finish()
        };
        let fresh = render(Expo::new());
        let reused = render(Expo::with_buffer(String::from("stale junk")));
        assert_eq!(fresh, reused);
        // A pre-grown buffer keeps its allocation across renders.
        let big = render(Expo::with_buffer(String::with_capacity(1 << 16)));
        assert_eq!(fresh, big);
        assert!(big.capacity() >= 1 << 16);
    }

    #[test]
    #[should_panic(expected = "duplicate metric family")]
    #[cfg(debug_assertions)]
    fn duplicate_family_panics_in_debug() {
        let mut e = Expo::new();
        e.counter("dup_total", "x", &[]);
        e.counter("dup_total", "x", &[]);
    }
}
