//! Lock-free metrics primitives: counters, gauges and fixed-boundary
//! histograms with atomic buckets.
//!
//! Everything here is recorded from hot paths (the daemon's submit
//! handler, the per-connection loop), so the write side is a bounded
//! number of `Relaxed` atomic adds — no allocation, no locks, no bucket
//! search loops ([`LatencyHist`] finds its bucket with one `leading_zeros`
//! instruction). Reads take a point-in-time [`HistSnapshot`] whose count
//! is *derived from the bucket values*, so every snapshot is internally
//! consistent (`le="+Inf"` cumulative count equals `_count` by
//! construction) even while writers race with the reader.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::time::Duration;

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge that can move both ways (e.g. open connections).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicI64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Finite bucket upper bounds of [`LatencyHist`], in nanoseconds:
/// `1µs · 2^i` for `i = 0..24` (1 µs up to ~8.4 s), doubling per bucket —
/// fixed log-spaced boundaries, so histograms from different shards,
/// threads or processes always merge bucket-for-bucket.
pub const LATENCY_BOUNDS: usize = 24;

/// Total bucket count: the finite bounds plus the overflow (`+Inf`) bucket.
pub const LATENCY_BUCKETS: usize = LATENCY_BOUNDS + 1;

/// Upper bound of finite latency bucket `i`, in nanoseconds.
#[inline]
pub fn latency_bound_ns(i: usize) -> u64 {
    1000u64 << i
}

/// Bucket index for a latency of `ns` nanoseconds: the smallest `i` with
/// `ns <= 1µs · 2^i`, or the overflow bucket. Branch-free except for the
/// overflow clamp: one division, one `leading_zeros`.
#[inline]
pub fn latency_bucket(ns: u64) -> usize {
    // Ceil to whole microseconds, then the bucket is ceil(log2(µs)).
    let us = ns.div_ceil(1000).max(1);
    let i = (64 - (us - 1).leading_zeros()) as usize;
    i.min(LATENCY_BOUNDS)
}

/// A latency histogram with fixed log-spaced boundaries and atomic
/// buckets. `record` is lock-free and allocation-free (two relaxed
/// `fetch_add`s and one on the chosen bucket), so shards and HTTP workers
/// share one instance without contention beyond cache-line traffic.
#[derive(Debug)]
pub struct LatencyHist {
    buckets: [AtomicU64; LATENCY_BUCKETS],
    sum_ns: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum_ns: AtomicU64::new(0) }
    }

    /// Record one observation of `ns` nanoseconds.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[latency_bucket(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one observed duration.
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Point-in-time snapshot in **seconds** (the Prometheus base unit).
    /// The count is the sum of the sampled buckets, so the snapshot's
    /// cumulative-bucket/`_count` relation holds even under concurrent
    /// writers; `sum` is read separately and may lag by in-flight records.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        HistSnapshot {
            bounds: (0..LATENCY_BOUNDS).map(|i| latency_bound_ns(i) as f64 / 1e9).collect(),
            buckets,
            sum: self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// Finite bucket upper bounds of [`DeltaHist`]: fragmentation-score deltas
/// are small signed integers, so symmetric powers of two around zero keep
/// the histogram sharp where commits actually land.
pub const DELTA_BOUNDS: [i64; 15] =
    [-64, -32, -16, -8, -4, -2, -1, 0, 1, 2, 4, 8, 16, 32, 64];

/// A histogram over signed integer values (ΔF per commit) with the same
/// atomic, lock-free recording contract as [`LatencyHist`].
#[derive(Debug)]
pub struct DeltaHist {
    buckets: [AtomicU64; DELTA_BOUNDS.len() + 1],
    sum: AtomicI64,
}

impl Default for DeltaHist {
    fn default() -> Self {
        Self::new()
    }
}

impl DeltaHist {
    pub fn new() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicI64::new(0) }
    }

    /// Record one signed observation. The bound scan is over 15 integers —
    /// still allocation- and lock-free; ΔF values cluster near zero so the
    /// scan usually stops early.
    #[inline]
    pub fn record(&self, v: i64) {
        let i = DELTA_BOUNDS
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(DELTA_BOUNDS.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time snapshot (native score units).
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            bounds: DELTA_BOUNDS.iter().map(|&b| b as f64).collect(),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed) as f64,
        }
    }
}

/// An owned, mergeable histogram snapshot: finite ascending `bounds` plus
/// per-bucket (non-cumulative) counts, with `buckets.len() == bounds.len()
/// + 1` (the last slot is the overflow bucket). Percentiles interpolate
/// linearly inside the winning bucket — the same estimator idiom as
/// [`crate::util::stats::Sample::percentile`], but over bucket edges
/// instead of stored values.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub bounds: Vec<f64>,
    pub buckets: Vec<u64>,
    pub sum: f64,
}

impl HistSnapshot {
    /// Total observations (always the sum of the buckets).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean observation (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum / n as f64
        }
    }

    /// Cumulative counts per finite bound, then the `+Inf` total — the
    /// Prometheus `_bucket` series.
    pub fn cumulative(&self) -> Vec<u64> {
        let mut acc = 0u64;
        self.buckets
            .iter()
            .map(|&b| {
                acc += b;
                acc
            })
            .collect()
    }

    /// Merge another snapshot (same boundaries) into this one —
    /// cross-shard / cross-thread aggregation.
    pub fn merge(&mut self, other: &HistSnapshot) {
        assert_eq!(self.bounds, other.bounds, "histograms must share boundaries to merge");
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.sum += other.sum;
    }

    /// Estimated `q`-th percentile (`q` in 0..=100) by linear
    /// interpolation inside the bucket containing that rank. Observations
    /// in the overflow bucket are reported as the largest finite bound
    /// (the histogram cannot see past it). Returns 0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 100.0) / 100.0 * n as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            if b == 0 {
                cum += b;
                continue;
            }
            let next = cum + b;
            if rank <= next as f64 {
                let Some(&hi) = self.bounds.get(i) else {
                    // Overflow bucket: clamp to the last finite bound.
                    return *self.bounds.last().unwrap_or(&0.0);
                };
                let lo = if i == 0 { hi.min(0.0) } else { self.bounds[i - 1] };
                let frac = (rank - cum as f64) / b as f64;
                return lo + (hi - lo) * frac;
            }
            cum = next;
        }
        *self.bounds.last().unwrap_or(&0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn latency_bucket_boundaries_are_inclusive_powers_of_two() {
        // Smallest bucket takes everything up to 1µs.
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1), 0);
        assert_eq!(latency_bucket(1000), 0);
        assert_eq!(latency_bucket(1001), 1);
        assert_eq!(latency_bucket(2000), 1);
        assert_eq!(latency_bucket(2001), 2);
        assert_eq!(latency_bucket(4000), 2);
        // 1 ms = bucket 10 (1µs · 2^10 = 1.024 ms bound).
        assert_eq!(latency_bucket(1_000_000), 10);
        // The largest finite bound is ~8.39 s; past it, overflow.
        assert_eq!(latency_bucket(latency_bound_ns(LATENCY_BOUNDS - 1)), LATENCY_BOUNDS - 1);
        assert_eq!(latency_bucket(latency_bound_ns(LATENCY_BOUNDS - 1) + 1), LATENCY_BOUNDS);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BOUNDS);
    }

    #[test]
    fn snapshot_count_and_cumulative_agree() {
        let h = LatencyHist::new();
        for ns in [10u64, 500, 1_000, 5_000, 1_000_000, 10_000_000_000] {
            h.record_ns(ns);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 6);
        let cum = s.cumulative();
        assert_eq!(*cum.last().unwrap(), 6, "+Inf cumulative equals count");
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "cumulative is monotone");
        // 3 observations at or under 1µs.
        assert_eq!(cum[0], 3);
        // The 10 s observation landed in the overflow bucket.
        assert_eq!(s.buckets[LATENCY_BOUNDS], 1);
    }

    #[test]
    fn merge_requires_matching_bounds_and_adds() {
        let a = LatencyHist::new();
        let b = LatencyHist::new();
        a.record_ns(100);
        a.record_ns(3_000);
        b.record_ns(3_000);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count(), 3);
        assert!((s.sum - 6_100.0 / 1e9).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate_and_clamp() {
        let h = LatencyHist::new();
        // 100 observations of ~1.5µs: all in bucket 1 (1µs, 2µs].
        for _ in 0..100 {
            h.record_ns(1_500);
        }
        let s = h.snapshot();
        let p50 = s.percentile(50.0);
        assert!(p50 > 1.0e-6 && p50 <= 2.0e-6, "p50 {p50} inside the bucket");
        assert!(s.percentile(99.0) <= 2.0e-6 + 1e-12);
        // Empty histogram.
        assert_eq!(LatencyHist::new().snapshot().percentile(50.0), 0.0);
        // Overflow-only histogram clamps to the last finite bound.
        let h = LatencyHist::new();
        h.record_ns(u64::MAX / 2);
        let s = h.snapshot();
        assert_eq!(s.percentile(50.0), *s.bounds.last().unwrap());
    }

    #[test]
    fn delta_hist_handles_signed_values() {
        let d = DeltaHist::new();
        for v in [-20i64, -1, 0, 0, 3, 100] {
            d.record(v);
        }
        let s = d.snapshot();
        assert_eq!(s.count(), 6);
        assert_eq!(s.sum, 82.0);
        let cum = s.cumulative();
        assert_eq!(*cum.last().unwrap(), 6);
        // -20 lands in the le=-16 bucket, 100 in the overflow bucket.
        let le_m16 = DELTA_BOUNDS.iter().position(|&b| b == -16).unwrap();
        assert_eq!(cum[le_m16], 1);
        assert_eq!(s.buckets[DELTA_BOUNDS.len()], 1);
        // Both zeros in the le=0 bucket.
        let le_0 = DELTA_BOUNDS.iter().position(|&b| b == 0).unwrap();
        assert_eq!(s.buckets[le_0], 2);
    }

    #[test]
    fn concurrent_recording_conserves_the_count() {
        // The lock-free contract: N threads × K records never lose a
        // sample, and a final snapshot's count equals the total.
        let h = Arc::new(LatencyHist::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record_ns((t * 1_000 + i) % 50_000_000);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.snapshot().count(), 80_000);
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.set(-3);
        assert_eq!(g.get(), -3);
    }
}
