//! Observability substrate: metrics primitives, Prometheus exposition,
//! structured logging and run telemetry — dependency-free, like the rest
//! of [`crate::util`].
//!
//! The layer is deliberately split from what it observes:
//!
//! * [`hist`] — lock-free atomic counters, gauges and fixed-boundary
//!   log-bucket histograms ([`hist::LatencyHist`]) that shards and worker
//!   threads record into concurrently and that merge into one snapshot
//!   ([`hist::HistSnapshot`], the percentile-interpolation idiom of
//!   [`crate::util::stats::Sample`]).
//! * [`expo`] — the Prometheus text exposition format (`# HELP`/`# TYPE`,
//!   label escaping, cumulative `_bucket` rendering) behind the daemon's
//!   `GET /metrics` endpoint.
//! * [`log`] — the leveled, RFC3339-timestamped (optionally JSON-lines)
//!   stderr logger driving the `log_error!`…`log_trace!` macros, plus the
//!   repeated-warning rate limiter used by the daemon's accept loop.
//! * [`telemetry`] — slot-cadence JSONL rows emitted by `sim::engine` and
//!   `sim::replay` under `--telemetry PATH`, so run trajectories (frag
//!   score, acceptance, migrations, decision-latency percentiles) become
//!   plottable artifacts.
//!
//! **Hot-path contract**: recording a sample is a bounded handful of
//! relaxed atomic increments — no allocation, no locks, no formatting —
//! so instrumenting the submit path costs nanoseconds (measured by
//! `benches/daemon_burst.rs`, reported as `hist_record_ns`).

pub mod expo;
pub mod hist;
pub mod log;
pub mod telemetry;

pub use expo::{Expo, Labels};
pub use hist::{Counter, DeltaHist, Gauge, HistSnapshot, LatencyHist};
