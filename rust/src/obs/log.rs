//! Leveled, RFC3339-timestamped stderr logger behind the
//! `log_error!`…`log_trace!` macros.
//!
//! * `MIGSCHED_LOG` selects the filter: `error|warn|info|debug|trace|off`
//!   (default `info`). `off` silences everything including errors.
//! * `MIGSCHED_LOG_FORMAT=json` switches from human-readable lines to
//!   JSON-lines (`{"ts":...,"level":...,"module":...,"msg":...}`), one
//!   object per line, escaped via [`crate::util::json`].
//! * [`RateLimited`] suppresses repeated identical warnings (the daemon's
//!   accept-error path) and reports how many were dropped when the same
//!   message is next allowed through.
//!
//! The level check is a single relaxed atomic load, so disabled log sites
//! cost one branch on the hot path.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Lowercase name for the JSON-lines `level` field.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

/// Filter slot values: 0..=4 map to [`Level`], `OFF` silences all sites,
/// `u8::MAX` means "not yet read from the environment".
const OFF: u8 = 5;
static FILTER: AtomicU8 = AtomicU8::new(u8::MAX);

/// Output format: 0 = text, 1 = JSON-lines, `u8::MAX` = uninitialized.
static FORMAT: AtomicU8 = AtomicU8::new(u8::MAX);

/// Parse a `MIGSCHED_LOG` value into a filter slot.
fn parse_filter(s: &str) -> Option<u8> {
    if s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("none") {
        return Some(OFF);
    }
    Level::from_str(s).map(|l| l as u8)
}

fn init_filter_from_env() -> u8 {
    let raw = std::env::var("MIGSCHED_LOG")
        .ok()
        .and_then(|v| parse_filter(&v))
        .unwrap_or(Level::Info as u8);
    FILTER.store(raw, Ordering::Relaxed);
    raw
}

fn filter() -> u8 {
    let raw = FILTER.load(Ordering::Relaxed);
    if raw == u8::MAX {
        init_filter_from_env()
    } else {
        raw
    }
}

fn json_format() -> bool {
    let raw = FORMAT.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return raw == 1;
    }
    let json = std::env::var("MIGSCHED_LOG_FORMAT")
        .map(|v| v.eq_ignore_ascii_case("json"))
        .unwrap_or(false);
    FORMAT.store(json as u8, Ordering::Relaxed);
    json
}

/// Current level when logging is on; `None` when the filter is `off`.
pub fn level() -> Option<Level> {
    match filter() {
        0 => Some(Level::Error),
        1 => Some(Level::Warn),
        2 => Some(Level::Info),
        3 => Some(Level::Debug),
        4 => Some(Level::Trace),
        _ => None,
    }
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(lvl: Level) {
    FILTER.store(lvl as u8, Ordering::Relaxed);
}

/// Silence every log site, including errors.
pub fn set_off() {
    FILTER.store(OFF, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    (lvl as u8) <= filter()
}

/// Days-since-epoch to (year, month, day) in the proleptic Gregorian
/// calendar — Howard Hinnant's `civil_from_days`, which keeps RFC3339
/// timestamps dependency-free.
fn civil_from_days(days: i64) -> (i64, u32, u32) {
    let z = days + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32; // [1, 12]
    (if m <= 2 { y + 1 } else { y }, m, d)
}

/// RFC3339 UTC timestamp with millisecond precision, e.g.
/// `2026-08-08T12:34:56.789Z`.
pub fn rfc3339_millis(t: SystemTime) -> String {
    let since = t.duration_since(UNIX_EPOCH).unwrap_or_default();
    let secs = since.as_secs();
    let millis = since.subsec_millis();
    let (y, mo, d) = civil_from_days((secs / 86_400) as i64);
    let rem = secs % 86_400;
    let (h, mi, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);
    format!("{y:04}-{mo:02}-{d:02}T{h:02}:{mi:02}:{s:02}.{millis:03}Z")
}

/// Emit one log line; prefer the macros.
pub fn log(lvl: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let ts = rfc3339_millis(SystemTime::now());
    if json_format() {
        let line = crate::util::json::Json::obj()
            .with("ts", ts.as_str())
            .with("level", lvl.name())
            .with("module", module)
            .with("msg", args.to_string())
            .to_string_compact();
        eprintln!("{line}");
    } else {
        eprintln!("{ts} {} {module}: {args}", lvl.tag());
    }
}

struct RateState {
    last_key: u64,
    last_emit: Option<Instant>,
    suppressed: u64,
}

/// Suppresses repeated identical messages inside a time window. Intended
/// for `static` use next to a noisy log site:
///
/// ```ignore
/// static ACCEPT_WARN: RateLimited = RateLimited::new(Duration::from_secs(5));
/// if let Some(dropped) = ACCEPT_WARN.should_log(&msg) {
///     if dropped > 0 { /* mention the dropped count */ }
///     log_warn!("{msg}");
/// }
/// ```
pub struct RateLimited {
    window: Duration,
    state: Mutex<RateState>,
}

impl RateLimited {
    pub const fn new(window: Duration) -> Self {
        Self {
            window,
            state: Mutex::new(RateState { last_key: 0, last_emit: None, suppressed: 0 }),
        }
    }

    /// FNV-1a over the message, so "identical" means byte-identical.
    fn hash(key: &str) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// `Some(previously_suppressed)` if the caller should emit this
    /// message now, `None` if it is a repeat inside the window. A changed
    /// message always logs immediately and resets the window.
    pub fn should_log(&self, key: &str) -> Option<u64> {
        let now = Instant::now();
        let h = Self::hash(key);
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let same = st.last_key == h;
        let within = st.last_emit.map(|t| now.duration_since(t) < self.window).unwrap_or(false);
        if same && within {
            st.suppressed += 1;
            return None;
        }
        let dropped = if same { st.suppressed } else { 0 };
        st.last_key = h;
        st.last_emit = Some(now);
        st.suppressed = 0;
        Some(dropped)
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::obs::log::log($crate::obs::log::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_includes_off() {
        assert_eq!(Level::from_str("ERROR"), Some(Level::Error));
        assert_eq!(Level::from_str("warning"), Some(Level::Warn));
        assert_eq!(Level::from_str("Info"), Some(Level::Info));
        assert_eq!(Level::from_str("nope"), None);
        assert_eq!(parse_filter("off"), Some(OFF));
        assert_eq!(parse_filter("OFF"), Some(OFF));
        assert_eq!(parse_filter("debug"), Some(Level::Debug as u8));
        assert_eq!(parse_filter("bogus"), None);
    }

    #[test]
    fn ordering_gates_and_off_silences_errors() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_off();
        assert!(!enabled(Level::Error));
        assert_eq!(level(), None);
        set_level(Level::Info); // restore default for other tests
        assert_eq!(level(), Some(Level::Info));
    }

    #[test]
    fn rfc3339_known_values() {
        assert_eq!(rfc3339_millis(UNIX_EPOCH), "1970-01-01T00:00:00.000Z");
        // 2000-02-29 (leap day) 00:00:00 UTC = 951782400.
        let leap = UNIX_EPOCH + Duration::from_secs(951_782_400);
        assert_eq!(rfc3339_millis(leap), "2000-02-29T00:00:00.000Z");
        // End of 2023 with millis: 1703980799.250 = 2023-12-30T23:59:59.250Z.
        let t = UNIX_EPOCH + Duration::from_millis(1_703_980_799_250);
        assert_eq!(rfc3339_millis(t), "2023-12-30T23:59:59.250Z");
    }

    #[test]
    fn rate_limiter_suppresses_repeats_and_resets_on_change() {
        let rl = RateLimited::new(Duration::from_secs(3600));
        assert_eq!(rl.should_log("boom"), Some(0));
        assert_eq!(rl.should_log("boom"), None);
        assert_eq!(rl.should_log("boom"), None);
        // A different message logs immediately (no carryover of the count).
        assert_eq!(rl.should_log("other"), Some(0));
        // Returning to the first message counts as a change again.
        assert_eq!(rl.should_log("boom"), Some(0));
        assert_eq!(rl.should_log("boom"), None);
    }

    #[test]
    fn zero_window_never_suppresses_and_reports_drops() {
        let rl = RateLimited::new(Duration::ZERO);
        assert_eq!(rl.should_log("x"), Some(0));
        assert_eq!(rl.should_log("x"), Some(0));
    }
}
