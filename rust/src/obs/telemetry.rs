//! Slot-cadence run telemetry: JSONL rows emitted by `sim::engine` and
//! `sim::replay` under `--telemetry PATH`.
//!
//! One row per recorded slot, so a run's trajectory (fragmentation,
//! acceptance, migrations, decision-latency percentiles) is plottable with
//! any JSONL-aware tool. Keys are fixed and documented in the README
//! "Observability" section; add new keys at the end rather than reordering
//! so downstream parsers stay stable.

use crate::obs::hist::HistSnapshot;
use crate::util::json::Json;

/// Point-in-time scalars for one telemetry row; the caller assembles this
/// from whatever engine it runs (closed-loop sim or open-loop replay).
#[derive(Clone, Copy, Debug, Default)]
pub struct SlotStats {
    pub slot: u64,
    pub arrived: u64,
    pub accepted: u64,
    pub allocated: usize,
    pub active_gpus: usize,
    pub utilization: f64,
    pub mean_frag_score: f64,
    pub migrations: u64,
    pub migrated_bytes: u64,
}

/// Render one JSONL row. `decisions` is the cumulative scheduler
/// decision-latency histogram at this slot; percentiles are in seconds.
pub fn slot_row(s: &SlotStats, decisions: &HistSnapshot) -> Json {
    let acceptance = if s.arrived > 0 { s.accepted as f64 / s.arrived as f64 } else { 1.0 };
    Json::obj()
        .with("slot", s.slot)
        .with("arrived", s.arrived)
        .with("accepted", s.accepted)
        .with("acceptance_rate", acceptance)
        .with("allocated", s.allocated)
        .with("utilization", s.utilization)
        .with("active_gpus", s.active_gpus)
        .with("mean_frag_score", s.mean_frag_score)
        .with("migrations", s.migrations)
        .with("migrated_bytes", s.migrated_bytes)
        .with("decisions", decisions.count())
        .with("decision_seconds_p50", decisions.percentile(50.0))
        .with("decision_seconds_p90", decisions.percentile(90.0))
        .with("decision_seconds_p99", decisions.percentile(99.0))
}

/// Write rows as one compact JSON object per line.
pub fn write_jsonl(path: &str, rows: &[Json]) -> std::io::Result<()> {
    use std::io::Write;
    let mut out = String::new();
    for row in rows {
        out.push_str(&row.to_string_compact());
        out.push('\n');
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(out.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::LatencyHist;

    #[test]
    fn row_has_the_documented_keys_and_rates() {
        let h = LatencyHist::new();
        h.record_ns(2_000); // 2µs
        h.record_ns(2_000);
        let stats = SlotStats {
            slot: 128,
            arrived: 10,
            accepted: 8,
            allocated: 5,
            active_gpus: 3,
            utilization: 0.75,
            mean_frag_score: 1.5,
            migrations: 2,
            migrated_bytes: 40,
        };
        let row = slot_row(&stats, &h.snapshot());
        assert_eq!(row.get("slot").and_then(Json::as_u64), Some(128));
        assert_eq!(row.get("acceptance_rate").and_then(Json::as_f64), Some(0.8));
        assert_eq!(row.get("decisions").and_then(Json::as_u64), Some(2));
        assert!(row.get("decision_seconds_p50").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(row.get("migrated_bytes").and_then(Json::as_u64), Some(40));
        // Zero arrivals does not divide by zero.
        let empty = slot_row(&SlotStats::default(), &LatencyHist::new().snapshot());
        assert_eq!(empty.get("acceptance_rate").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn jsonl_writes_one_object_per_line() {
        let dir = std::env::temp_dir().join("migsched_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rows.jsonl");
        let rows = vec![Json::obj().with("slot", 0u64), Json::obj().with("slot", 1u64)];
        write_jsonl(path.to_str().unwrap(), &rows).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with('{') && lines[0].ends_with('}'));
        std::fs::remove_file(&path).ok();
    }
}
