//! Distribution-aware scoring conformance and drift behaviour.
//!
//! * With an *empty* or *uniform* estimator, `MFI-EXP` must be
//!   bit-identical to flat `MFI` on ANY interleaving of schedule / commit
//!   / release operations — empty mixes fall back to the agnostic scorer
//!   outright, and a uniform mix scales every table entry by one shared
//!   constant, preserving the strict `(ΔF, gpu, anchor)` order including
//!   ties. (The multi-class fleet version lives in `tests/fleet.rs`.)
//! * Mid-trace mix shift (ROADMAP drift test): replaying a skew-small →
//!   skew-big trace, the online estimator re-converges to the new mix
//!   within a bounded number of arrivals, and `MFI-EXP`'s acceptance does
//!   not collapse below the agnostic baseline under real pressure.

use migsched::cluster::Cluster;
use migsched::mig::{HardwareModel, Profile};
use migsched::sched::{Mfi, MfiExpected, Scheduler, SchedulerKind};
use migsched::sim::replay::{self, ReplayConfig};
use migsched::util::check::forall_shrink_vec;
use migsched::util::rng::Rng;
use migsched::workload::{
    Distribution, EstimatorConfig, Trace, WorkloadGenerator, WorkloadId,
};

/// Replay an op-encoded episode against flat MFI and two degenerate
/// MFI-EXP instances on one shared cluster; every proposal must match.
/// Encoding (shrinkable `Vec<u64>`): `op % 4 < 3` → arrival of profile
/// `(op / 4) % 6`; `op % 4 == 3` → release of the `(op / 4) % live`-th
/// oldest live workload.
fn drive_and_compare(ops: &[u64], gpus: usize) -> Result<(), String> {
    let hw = HardwareModel::a100_80gb();
    let mut flat = Mfi::for_hardware(&hw);
    let mut empty = MfiExpected::for_hardware(&hw);
    let uniform_cfg = EstimatorConfig { decay_slots: 0, seed_counts: Some([1; 6]) };
    let mut uniform = MfiExpected::with_config(&hw, &uniform_cfg);
    let mut cluster = Cluster::new(hw, gpus);
    let mut live: Vec<WorkloadId> = Vec::new();
    let mut next_id = 0u64;
    for (step, &op) in ops.iter().enumerate() {
        if op % 4 < 3 || live.is_empty() {
            let profile = Profile::from_index(((op / 4) % 6) as usize).unwrap();
            let want = flat.schedule(&cluster, profile);
            // The estimators are deliberately never fed `on_commit`: the
            // property is about the empty/uniform mix, not the online one.
            let got_empty = empty.schedule(&cluster, profile);
            let got_uniform = uniform.schedule(&cluster, profile);
            if got_empty != want || got_uniform != want {
                return Err(format!(
                    "step {step}: {profile} → MFI {want:?} vs MFI-EXP(empty) \
                     {got_empty:?} vs MFI-EXP(uniform) {got_uniform:?}"
                ));
            }
            if let Some(placement) = want {
                let id = WorkloadId(next_id);
                next_id += 1;
                cluster.allocate(id, placement).map_err(|e| format!("step {step}: {e}"))?;
                live.push(id);
            }
        } else {
            let victim = live.remove(((op / 4) as usize) % live.len());
            cluster.release(victim).map_err(|e| format!("step {step}: {e}"))?;
        }
    }
    Ok(())
}

#[test]
fn prop_empty_and_uniform_mfi_exp_equal_flat_mfi() {
    forall_shrink_vec(
        "mfi-exp-degenerate-equivalence",
        |rng| (0..rng.index(120)).map(|_| rng.next_u64()).collect(),
        |ops| drive_and_compare(ops, 4),
    );
}

/// Two concatenated open-loop segments with the same arrival cadence but
/// inverted profile mixes: skew-small (1g.10gb-dominated, 30% vs 5% for
/// 7g.80gb) followed by skew-big (the exact inversion).
fn shifted_trace(per_segment: usize, seed: u64) -> Trace {
    let small = WorkloadGenerator::new(Distribution::SkewSmall)
        .with_tenants(5)
        .generate_stream(per_segment, 0.35, 40, &mut Rng::new(seed));
    let mut big = WorkloadGenerator::new(Distribution::SkewBig)
        .with_tenants(5)
        .generate_stream(per_segment, 0.35, 40, &mut Rng::new(seed ^ 0x5eed));
    let id_offset = small.len() as u64;
    let slot_offset = small.last().map(|w| w.arrival_slot + 1).unwrap_or(0);
    for w in &mut big {
        w.id = WorkloadId(w.id.0 + id_offset);
        w.arrival_slot += slot_offset;
    }
    let mut all = small;
    all.extend(big);
    Trace::from_workloads("mix shift: skew-small then skew-big", 448, &all)
}

#[test]
fn estimator_reconverges_after_a_mid_trace_mix_shift() {
    let trace = shifted_trace(700, 7);
    let hw = HardwareModel::a100_80gb();
    // Generous capacity: acceptance stays near 1 on both arms, so the
    // estimator sees (essentially) the arrival stream itself.
    let config = ReplayConfig::new(64);
    let est = EstimatorConfig { decay_slots: 96, seed_counts: None };
    let mut sched = SchedulerKind::MfiExp.build_with_estimator(&hw, Some(&est));
    let result = replay::run(&trace, &mut *sched, &config);
    assert!(result.conserved());
    assert!(
        result.acceptance_rate() > 0.9,
        "capacity was sized for near-full acceptance, got {}",
        result.acceptance_rate()
    );
    let mix = sched.estimator().expect("MFI-EXP exposes its estimator");
    let shares = mix.normalized();
    let big = shares[Profile::P7g80gb.index()];
    let small = shares[Profile::P1g10gb.index()];
    // After ~700 post-shift arrivals with D = 96, segment A's mass
    // retains (1 - 1/96)^700 ≈ e^(-7.3) < 0.1% — the estimator must have
    // flipped from 1g.10gb-dominated to 7g.80gb-dominated.
    assert!(big > small, "estimator did not re-converge: 7g={big:.3} 1g={small:.3}");
    assert!(big > 0.15, "7g.80gb share should approach its 30% arrival share: {big:.3}");
    assert!(small < 0.15, "1g.10gb share should decay toward its 5% arrival share: {small:.3}");
}

#[test]
fn mfi_exp_acceptance_does_not_collapse_on_the_shifted_tail() {
    // ~3x overload so rejections are real, not incidental.
    let trace = shifted_trace(700, 11);
    let hw = HardwareModel::a100_80gb();
    let config = ReplayConfig::new(12);
    let mut mfi = SchedulerKind::Mfi.build(&hw);
    let base = replay::run(&trace, &mut *mfi, &config);
    let est = EstimatorConfig { decay_slots: 96, seed_counts: None };
    let mut exp = SchedulerKind::MfiExp.build_with_estimator(&hw, Some(&est));
    let aware = replay::run(&trace, &mut *exp, &config);
    assert!(base.conserved() && aware.conserved());
    assert!(
        base.accepted > 0 && base.rejected > 0,
        "pressure check: accepted={} rejected={}",
        base.accepted,
        base.rejected
    );
    assert!(
        aware.accepted as f64 >= 0.9 * base.accepted as f64,
        "MFI-EXP collapsed on the shifted trace: {} vs MFI {}",
        aware.accepted,
        base.accepted
    );
}
