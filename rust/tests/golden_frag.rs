//! Golden-oracle conformance: the rust fragmentation engines must match
//! the python reference kernel (`python/compile/kernels/ref.py`, the jnp
//! specification the Pallas kernel and the AOT artifact are verified
//! against) **bit-for-bit** on every one of the 256 occupancy patterns.
//!
//! The fixture `tests/golden/frag_golden.json` is exported from the python
//! oracle (see README "Regenerating the golden fixture") and checked in,
//! so the cross-language contract is enforced without python in the test
//! loop:
//!
//! * `scores_partial[m]` / `scores_any[m]` — Algorithm 1 scores of mask
//!   `m` under both overlap rules;
//! * `deltas_partial[m][k]` — ΔF of candidate `k` ([`CANDIDATES`] order)
//!   under the default rule, `1e9` sentinel when infeasible;
//! * `feasible[m][k]` — 1 iff candidate `k`'s window is free on mask `m`.

use migsched::frag::{score_direct_rule, FragScorer, OverlapRule, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, Profile, CANDIDATES, NUM_CANDIDATES};
use migsched::runtime::{NativeFragEngine, INFEASIBLE_DELTA};
use migsched::util::json::Json;

const FIXTURE: &str = include_str!("golden/frag_golden.json");

fn fixture() -> Json {
    let j = Json::parse(FIXTURE).expect("golden fixture parses");
    assert_eq!(j.req_str("format").unwrap(), "migsched-golden-frag-v3");
    assert_eq!(j.req_u64("num_slices").unwrap(), 8);
    assert_eq!(j.req_u64("num_candidates").unwrap() as usize, NUM_CANDIDATES);
    j
}

fn u32_vec(j: &Json, key: &str) -> Vec<u32> {
    j.get(key)
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("fixture missing '{key}'"))
        .iter()
        .map(|v| v.as_u64().expect("integral score") as u32)
        .collect()
}

#[test]
fn score_table_matches_python_oracle_bit_for_bit() {
    let j = fixture();
    let hw = HardwareModel::a100_80gb();
    for (key, rule) in [("scores_partial", OverlapRule::Partial), ("scores_any", OverlapRule::Any)]
    {
        let golden = u32_vec(&j, key);
        assert_eq!(golden.len(), 256, "{key}");
        let table = ScoreTable::for_hardware_rule(&hw, rule);
        for (mask, &expect) in golden.iter().enumerate() {
            let g = GpuState::from_mask(mask as u8);
            assert_eq!(
                table.score(g),
                expect,
                "{key}: ScoreTable disagrees with python oracle at occ={mask:#010b}"
            );
            assert_eq!(
                score_direct_rule(g, &hw, rule),
                expect,
                "{key}: score_direct disagrees with python oracle at occ={mask:#010b}"
            );
        }
    }
}

#[test]
fn paper_worked_examples_present_in_fixture() {
    // The fixture itself must encode the paper's Section V-B narrative —
    // guards against regenerating it from a drifted oracle.
    let j = fixture();
    let partial = u32_vec(&j, "scores_partial");
    let any = u32_vec(&j, "scores_any");
    // GPU 2 of Fig. 3a: {2g.20gb@0, 1g.10gb@5} → occupied slices 0,1,5.
    assert_eq!(partial[0b0010_0011], 16, "paper: F(GPU 2) = 16");
    // GPU 1: {1g.10gb@5}.
    assert_eq!(partial[0b0010_0000], 8, "paper: F(GPU 1) = 8");
    // Misplaced 1g.10gb at index 1 (Section V-B motivation).
    assert_eq!(partial[0b0000_0010], 12);
    // Saturated and empty GPUs are unfragmented under both rules.
    assert_eq!(partial[0x00], 0);
    assert_eq!(partial[0xFF], 0);
    assert_eq!(any[0x00], 0);
    assert_eq!(any[0xFF], 0);
    // The literal any-overlap rule diverges on the worked example.
    assert_eq!(any[0b0010_0011], 23);
    // Bound: F ≤ max_score(A100) = 41 everywhere.
    assert!(partial.iter().chain(any.iter()).all(|&f| f <= 41));
}

#[test]
fn deltas_and_feasibility_match_python_oracle() {
    let j = fixture();
    let sentinel = j.req_u64("infeasible_sentinel").unwrap() as f64;
    assert_eq!(sentinel as f32, INFEASIBLE_DELTA);
    let deltas = j.get("deltas_partial").and_then(Json::as_arr).expect("deltas_partial");
    let feasible = j.get("feasible").and_then(Json::as_arr).expect("feasible");
    assert_eq!(deltas.len(), 256);
    assert_eq!(feasible.len(), 256);

    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);
    let engine = NativeFragEngine::new(&hw);
    let masks: Vec<u8> = (0..=255u8).collect();
    let batch = engine.evaluate(&masks).expect("native evaluate");

    for mask in 0..256usize {
        let g = GpuState::from_mask(mask as u8);
        let drow = deltas[mask].as_arr().expect("delta row");
        let frow = feasible[mask].as_arr().expect("feasible row");
        assert_eq!(drow.len(), NUM_CANDIDATES);
        assert_eq!(frow.len(), NUM_CANDIDATES);
        for (c, cand) in CANDIDATES.iter().enumerate() {
            let oracle_feasible = frow[c].as_u64().expect("0/1") == 1;
            assert_eq!(
                g.fits_at(cand.profile, cand.start),
                oracle_feasible,
                "feasibility occ={mask:#010b} cand={c}"
            );
            assert_eq!(batch.feasible[mask][c], oracle_feasible);
            let oracle_delta = drow[c].as_f64().expect("numeric delta");
            if oracle_feasible {
                let native = table.delta(g, cand.profile, cand.start);
                assert_eq!(
                    native as f64, oracle_delta,
                    "ΔF occ={mask:#010b} cand={}@{}",
                    cand.profile, cand.start
                );
                assert_eq!(batch.deltas[mask][c] as f64, oracle_delta);
            } else {
                assert_eq!(oracle_delta, sentinel, "occ={mask:#010b} cand={c}");
                assert_eq!(batch.deltas[mask][c], INFEASIBLE_DELTA);
            }
        }
    }
}

/// Any-rule ΔF (fixture v3): the literal-Algorithm-1 overlap rule's delta
/// table must match the oracle for every (mask, candidate) pair, and be
/// consistent with the any-rule score table (ΔF = F(m ∪ w) − F(m), which
/// the any rule — unlike partial — can drive negative).
#[test]
fn any_rule_deltas_match_python_oracle() {
    let j = fixture();
    let sentinel = j.req_u64("infeasible_sentinel").unwrap() as i64;
    let deltas = j.get("deltas_any").and_then(Json::as_arr).expect("deltas_any");
    let feasible = j.get("feasible").and_then(Json::as_arr).expect("feasible");
    let scores = u32_vec(&j, "scores_any");
    assert_eq!(deltas.len(), 256);
    let table = ScoreTable::for_hardware_rule(&HardwareModel::a100_80gb(), OverlapRule::Any);
    let mut saw_negative = false;
    for mask in 0..256usize {
        let g = GpuState::from_mask(mask as u8);
        let drow = deltas[mask].as_arr().expect("delta row");
        let frow = feasible[mask].as_arr().expect("feasible row");
        assert_eq!(drow.len(), NUM_CANDIDATES);
        for (c, cand) in CANDIDATES.iter().enumerate() {
            let oracle_delta = drow[c].as_f64().expect("numeric delta") as i64;
            if frow[c].as_u64().expect("0/1") == 1 {
                assert_eq!(
                    i64::from(table.delta(g, cand.profile, cand.start)),
                    oracle_delta,
                    "any-rule ΔF occ={mask:#010b} cand={}@{}",
                    cand.profile,
                    cand.start
                );
                let after = mask | cand.mask as usize;
                assert_eq!(
                    oracle_delta,
                    i64::from(scores[after]) - i64::from(scores[mask]),
                    "fixture any-rule tables disagree at occ={mask:#010b} cand={c}"
                );
                saw_negative |= oracle_delta < 0;
            } else {
                assert_eq!(oracle_delta, sentinel, "occ={mask:#010b} cand={c}");
            }
        }
    }
    assert!(saw_negative, "the any rule is known to produce negative ΔF somewhere");
}

/// The `subsets` combos (fixture v3): two further profile-subset tables
/// beyond `restricted_*`, each checked bit-for-bit against the rust
/// `ScoreTable`. Scores weight candidates in slice units, so the same
/// oracle tables pin every model sharing the 8-slice geometry — the loop
/// runs them against A100-80GB, **A100-40GB** and H100 (per-class
/// `profile_mem_gb` differs; Algorithm 1's arithmetic must not).
#[test]
fn subset_combo_tables_match_python_oracle_across_models() {
    let j = fixture();
    let sentinel = j.req_u64("infeasible_sentinel").unwrap() as i64;
    let full = u32_vec(&j, "scores_partial");
    let subsets = j.get("subsets").and_then(Json::as_arr).expect("subsets");
    assert!(subsets.len() >= 2, "fixture must carry at least two extra combos");
    let models = [
        HardwareModel::a100_80gb(),
        HardwareModel::a100_40gb(),
        HardwareModel::h100_80gb(),
    ];
    for sub in subsets {
        let profiles: Vec<Profile> = sub
            .get("profiles")
            .and_then(Json::as_arr)
            .expect("subset profiles")
            .iter()
            .map(|v| Profile::parse(v.as_str().expect("name")).expect("known profile"))
            .collect();
        let cand_idx: Vec<usize> = sub
            .get("candidates")
            .and_then(Json::as_arr)
            .expect("subset candidates")
            .iter()
            .map(|v| v.as_u64().expect("index") as usize)
            .collect();
        let scores = u32_vec(sub, "scores");
        let max_score = sub.req_u64("max_score").unwrap() as u32;
        let deltas = sub.get("deltas").and_then(Json::as_arr).expect("subset deltas");
        let feasible = sub.get("feasible").and_then(Json::as_arr).expect("subset feasible");
        for base in &models {
            let hw = base.clone().with_profiles(&profiles);
            let table = ScoreTable::for_hardware(&hw);
            assert_eq!(
                *table.raw().iter().max().unwrap() as u32,
                max_score,
                "{}: index bucket offset disagrees with oracle",
                hw.name()
            );
            for mask in 0..256usize {
                let g = GpuState::from_mask(mask as u8);
                assert_eq!(
                    table.score(g),
                    scores[mask],
                    "{}: subset score disagrees at occ={mask:#010b}",
                    hw.name()
                );
                assert!(scores[mask] <= full[mask], "subset score exceeds full set");
                let drow = deltas[mask].as_arr().expect("delta row");
                let frow = feasible[mask].as_arr().expect("feasible row");
                assert_eq!(drow.len(), cand_idx.len());
                for (col, &c) in cand_idx.iter().enumerate() {
                    let cand = &CANDIDATES[c];
                    let oracle_feasible = frow[col].as_u64().expect("0/1") == 1;
                    assert_eq!(g.fits_at(cand.profile, cand.start), oracle_feasible);
                    let oracle_delta = drow[col].as_f64().expect("numeric") as i64;
                    if oracle_feasible {
                        assert_eq!(
                            i64::from(table.delta(g, cand.profile, cand.start)),
                            oracle_delta,
                            "{}: occ={mask:#010b} cand={c}",
                            hw.name()
                        );
                        assert!(oracle_delta.unsigned_abs() <= u64::from(max_score));
                    } else {
                        assert_eq!(oracle_delta, sentinel);
                    }
                }
            }
        }
    }
}

/// The restricted-profile-set tables (fixture v2): scores and ΔF under
/// `HardwareModel::with_profiles(&[3g.40gb, 1g.10gb])` — the subset knob
/// the python oracle grew for exactly this export — must match the rust
/// `ScoreTable` bit-for-bit, and every feasible ΔF must respect the
/// exported `max_score_restricted` bound. That bound is precisely the
/// bucket offset `frag::FragIndex` derives from the table
/// (`max(ScoreTable::raw())`), so the index's bucket range for restricted
/// profile sets is pinned against the oracle.
#[test]
fn restricted_profile_set_matches_python_oracle() {
    let j = fixture();
    let names: Vec<&str> = j
        .get("restricted_profiles")
        .and_then(Json::as_arr)
        .expect("restricted_profiles")
        .iter()
        .map(|v| v.as_str().expect("profile name"))
        .collect();
    let profiles: Vec<Profile> =
        names.iter().map(|n| Profile::parse(n).expect("known profile")).collect();
    let hw = HardwareModel::a100_80gb().with_profiles(&profiles);
    let table = ScoreTable::for_hardware(&hw);

    // Candidate columns of the restricted table, in frozen CANDIDATES order.
    let cand_idx: Vec<usize> = j
        .get("restricted_candidates")
        .and_then(Json::as_arr)
        .expect("restricted_candidates")
        .iter()
        .map(|v| v.as_u64().expect("index") as usize)
        .collect();
    for &c in &cand_idx {
        assert!(profiles.contains(&CANDIDATES[c].profile), "candidate {c} outside subset");
    }
    assert_eq!(
        cand_idx.len(),
        profiles.iter().map(|p| p.starts().len()).sum::<usize>(),
        "subset candidate count"
    );

    let scores = u32_vec(&j, "scores_restricted");
    let full = u32_vec(&j, "scores_partial");
    assert_eq!(scores.len(), 256);
    let max_restricted = j.req_u64("max_score_restricted").unwrap() as u32;
    let sentinel = j.req_u64("infeasible_sentinel").unwrap() as i64;
    let deltas = j.get("deltas_restricted").and_then(Json::as_arr).expect("deltas_restricted");
    let feasible =
        j.get("feasible_restricted").and_then(Json::as_arr).expect("feasible_restricted");

    // The bucket offset the index derives for this table == the oracle max.
    assert_eq!(*table.raw().iter().max().unwrap() as u32, max_restricted);

    for mask in 0..256usize {
        let g = GpuState::from_mask(mask as u8);
        assert_eq!(
            table.score(g),
            scores[mask],
            "restricted score disagrees with oracle at occ={mask:#010b}"
        );
        assert!(scores[mask] <= full[mask], "subset score exceeds full-set score");
        let drow = deltas[mask].as_arr().expect("delta row");
        let frow = feasible[mask].as_arr().expect("feasible row");
        assert_eq!(drow.len(), cand_idx.len());
        for (col, &c) in cand_idx.iter().enumerate() {
            let cand = &CANDIDATES[c];
            let oracle_feasible = frow[col].as_u64().expect("0/1") == 1;
            assert_eq!(g.fits_at(cand.profile, cand.start), oracle_feasible);
            let oracle_delta = drow[col].as_f64().expect("numeric") as i64;
            if oracle_feasible {
                let native = table.delta(g, cand.profile, cand.start) as i64;
                assert_eq!(native, oracle_delta, "occ={mask:#010b} cand={c}");
                // ΔF stays inside the index's bucket range [-max, +max].
                assert!(
                    oracle_delta.unsigned_abs() <= max_restricted as u64,
                    "ΔF {oracle_delta} escapes bucket bound {max_restricted}"
                );
            } else {
                assert_eq!(oracle_delta, sentinel);
            }
        }
    }
}

#[test]
fn fixture_is_internally_consistent() {
    // Partial-rule scores must satisfy F(m ∪ w) - F(m) == deltas[m][k]
    // for feasible candidates — i.e. the fixture's two tables agree with
    // each other, independent of the rust implementation.
    let j = fixture();
    let scores = u32_vec(&j, "scores_partial");
    let deltas = j.get("deltas_partial").and_then(Json::as_arr).unwrap();
    let feasible = j.get("feasible").and_then(Json::as_arr).unwrap();
    for mask in 0..256usize {
        let drow = deltas[mask].as_arr().unwrap();
        let frow = feasible[mask].as_arr().unwrap();
        for (c, cand) in CANDIDATES.iter().enumerate() {
            if frow[c].as_u64().unwrap() != 1 {
                continue;
            }
            let after = mask | cand.mask as usize;
            let expect = scores[after] as f64 - scores[mask] as f64;
            assert_eq!(drow[c].as_f64().unwrap(), expect, "occ={mask:#010b} cand={c}");
        }
    }
    // And the profile used by the worked examples really is Table I's.
    assert_eq!(Profile::P1g10gb.mask_at(5), 0b0010_0000);
}
