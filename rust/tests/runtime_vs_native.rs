//! End-to-end numeric validation of the three-layer stack: the AOT
//! artifact (JAX + Pallas, compiled through PJRT) must agree bit-for-bit
//! with the native rust fragmentation engine, and the `MfiXla` scheduler
//! must take decision-for-decision the same actions as native `Mfi`.
//!
//! Requires `make artifacts`; tests skip (with a loud message) when the
//! artifacts directory is missing so `cargo test` works pre-build.

use migsched::frag::{FragScorer, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, CANDIDATES, NUM_CANDIDATES};
use migsched::runtime::{artifacts_dir, FragEngine, PjrtRuntime};
use migsched::sched::{Mfi, MfiXla, Scheduler};
use migsched::util::rng::Rng;

fn engine_or_skip() -> Option<(PjrtRuntime, FragEngine)> {
    let dir = artifacts_dir();
    if !dir.join("frag.hlo.txt").exists() {
        eprintln!(
            "SKIP: {}/frag.hlo.txt missing — run `make artifacts` first",
            dir.display()
        );
        return None;
    }
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    let engine = FragEngine::load_default(&runtime).expect("loading artifact");
    Some((runtime, engine))
}

fn random_reachable_state(rng: &mut Rng) -> GpuState {
    let mut g = GpuState::empty();
    for _ in 0..rng.index(6) {
        let p = *rng.choose(&migsched::mig::ALL_PROFILES);
        let feasible: Vec<u8> = g.feasible_indexes(p).collect();
        if feasible.is_empty() {
            continue;
        }
        g = g.with_placement(p, *rng.choose(&feasible));
    }
    g
}

#[test]
fn artifact_scores_match_native_exhaustively() {
    let Some((_rt, engine)) = engine_or_skip() else { return };
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    // All 256 occupancy masks in one batched evaluation.
    let masks: Vec<u8> = (0..=255u8).collect();
    let batch = engine.evaluate(&masks).expect("evaluate");
    assert_eq!(batch.scores.len(), 256);
    for (i, &mask) in masks.iter().enumerate() {
        let native = table.score(GpuState::from_mask(mask)) as f32;
        assert_eq!(batch.scores[i], native, "score mismatch at occ={mask:#010b}");
    }
}

#[test]
fn artifact_deltas_and_feasibility_match_native() {
    let Some((_rt, engine)) = engine_or_skip() else { return };
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    let masks: Vec<u8> = (0..=255u8).collect();
    let batch = engine.evaluate(&masks).expect("evaluate");
    for (i, &mask) in masks.iter().enumerate() {
        let g = GpuState::from_mask(mask);
        for (c, cand) in CANDIDATES.iter().enumerate() {
            let native_feasible = g.fits_at(cand.profile, cand.start);
            assert_eq!(
                batch.feasible[i][c], native_feasible,
                "feasibility mismatch occ={mask:#010b} cand={c}"
            );
            if native_feasible {
                let native_delta = table.delta(g, cand.profile, cand.start) as f32;
                assert_eq!(
                    batch.deltas[i][c], native_delta,
                    "delta mismatch occ={mask:#010b} cand={}@{}",
                    cand.profile, cand.start
                );
            } else {
                assert!(batch.deltas[i][c] > 1e8, "infeasible sentinel missing");
            }
        }
    }
}

#[test]
fn chunking_handles_clusters_larger_than_batch() {
    let Some((_rt, engine)) = engine_or_skip() else { return };
    let b = engine.batch_size();
    // A cluster 2.5× the artifact batch exercises the chunk+pad path.
    let mut rng = Rng::new(99);
    let masks: Vec<u8> = (0..b * 5 / 2).map(|_| random_reachable_state(&mut rng).mask()).collect();
    let batch = engine.evaluate(&masks).expect("evaluate");
    assert_eq!(batch.scores.len(), masks.len());
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    for (i, &mask) in masks.iter().enumerate() {
        assert_eq!(batch.scores[i], table.score(GpuState::from_mask(mask)) as f32);
    }
}

#[test]
fn mfi_xla_matches_native_mfi_decisions() {
    let Some((rt, _)) = engine_or_skip() else { return };
    let hw = HardwareModel::a100_80gb();
    let mut native = Mfi::for_hardware(&hw);
    let mut xla = MfiXla::load_default(&rt).expect("loading MfiXla");

    let mut rng = Rng::new(0xABCD);
    for round in 0..30 {
        // Drive BOTH schedulers through an identical random episode.
        let mut cluster = migsched::cluster::Cluster::new(hw.clone(), 6);
        let mut next_id = 0u64;
        for step in 0..80 {
            let p = *rng.choose(&migsched::mig::ALL_PROFILES);
            let a = native.schedule(&cluster, p);
            let b = xla.schedule(&cluster, p);
            assert_eq!(a, b, "round {round} step {step}: decision divergence for {p}");
            if let Some(pl) = a {
                cluster
                    .allocate(migsched::workload::WorkloadId(next_id), pl)
                    .expect("valid placement");
                next_id += 1;
            }
            if rng.chance(0.3) && cluster.allocated_workloads() > 0 {
                let ids: Vec<_> = cluster.allocations().map(|(id, _)| id).collect();
                cluster.release(*rng.choose(&ids)).unwrap();
            }
        }
    }
}

#[test]
fn frag_engine_metadata() {
    let Some((_rt, engine)) = engine_or_skip() else { return };
    assert!(engine.batch_size() >= 1);
    assert_eq!(engine.rule(), "partial");
    // NUM_CANDIDATES is frozen between the two languages.
    assert_eq!(NUM_CANDIDATES, 18);
}

#[test]
fn mean_score_agreement_on_random_clusters() {
    let Some((_rt, engine)) = engine_or_skip() else { return };
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    let mut rng = Rng::new(2025);
    for _ in 0..10 {
        let gpus: Vec<GpuState> = (0..100).map(|_| random_reachable_state(&mut rng)).collect();
        let masks: Vec<u8> = gpus.iter().map(|g| g.mask()).collect();
        let batch = engine.evaluate(&masks).unwrap();
        let xla_mean =
            batch.scores.iter().map(|&s| s as f64).sum::<f64>() / gpus.len() as f64;
        let native_mean = table.mean_score(&gpus);
        assert!((xla_mean - native_mean).abs() < 1e-9, "{xla_mean} vs {native_mean}");
    }
}
