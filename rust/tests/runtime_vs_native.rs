//! Engine-contract validation.
//!
//! Default build: the pure-rust [`NativeFragEngine`] must agree bit-for-bit
//! with the 256-entry score table and with the checked-in python-oracle
//! golden fixture semantics (scores, ΔF, feasibility, sentinel).
//!
//! With `--features xla` (requires the PJRT-binding crate and
//! `make artifacts`): the AOT artifact (JAX + Pallas, compiled through
//! PJRT) must agree bit-for-bit with the native engine, and the `MfiXla`
//! scheduler must take decision-for-decision the same actions as native
//! `Mfi`. Those tests skip with a loud message when the artifacts
//! directory is missing so `cargo test --features xla` works pre-build.

use migsched::frag::{FragScorer, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, CANDIDATES, NUM_CANDIDATES};
use migsched::runtime::{FragBatch, NativeFragEngine, INFEASIBLE_DELTA};
use migsched::util::rng::Rng;

fn random_reachable_state(rng: &mut Rng) -> GpuState {
    let mut g = GpuState::empty();
    for _ in 0..rng.index(6) {
        let p = *rng.choose(&migsched::mig::ALL_PROFILES);
        let feasible: Vec<u8> = g.feasible_indexes(p).collect();
        if feasible.is_empty() {
            continue;
        }
        g = g.with_placement(p, *rng.choose(&feasible));
    }
    g
}

fn assert_batch_matches_table(batch: &FragBatch, masks: &[u8], table: &ScoreTable) {
    assert_eq!(batch.scores.len(), masks.len());
    for (i, &mask) in masks.iter().enumerate() {
        let g = GpuState::from_mask(mask);
        assert_eq!(batch.scores[i], table.score(g) as f32, "score mismatch occ={mask:#010b}");
        for (c, cand) in CANDIDATES.iter().enumerate() {
            let native_feasible = g.fits_at(cand.profile, cand.start);
            assert_eq!(
                batch.feasible[i][c], native_feasible,
                "feasibility mismatch occ={mask:#010b} cand={c}"
            );
            if native_feasible {
                assert_eq!(
                    batch.deltas[i][c],
                    table.delta(g, cand.profile, cand.start) as f32,
                    "delta mismatch occ={mask:#010b} cand={}@{}",
                    cand.profile,
                    cand.start
                );
            } else {
                assert_eq!(batch.deltas[i][c], INFEASIBLE_DELTA, "sentinel missing");
            }
        }
    }
}

#[test]
fn native_engine_matches_table_exhaustively() {
    let engine = NativeFragEngine::new(&HardwareModel::a100_80gb());
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    let masks: Vec<u8> = (0..=255u8).collect();
    let batch = engine.evaluate(&masks).expect("native evaluate");
    assert_batch_matches_table(&batch, &masks, &table);
}

#[test]
fn native_engine_on_random_clusters() {
    let engine = NativeFragEngine::new(&HardwareModel::a100_80gb());
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    let mut rng = Rng::new(2025);
    for _ in 0..10 {
        let gpus: Vec<GpuState> = (0..100).map(|_| random_reachable_state(&mut rng)).collect();
        let masks: Vec<u8> = gpus.iter().map(|g| g.mask()).collect();
        let batch = engine.evaluate(&masks).unwrap();
        assert_batch_matches_table(&batch, &masks, &table);
        let batch_mean =
            batch.scores.iter().map(|&s| s as f64).sum::<f64>() / gpus.len() as f64;
        assert!((batch_mean - table.mean_score(&gpus)).abs() < 1e-9);
    }
}

#[test]
fn native_engine_metadata() {
    let engine = NativeFragEngine::new(&HardwareModel::a100_80gb());
    assert_eq!(engine.rule(), "partial");
    // NUM_CANDIDATES is frozen between the rust and python layers.
    assert_eq!(NUM_CANDIDATES, 18);
}

// ---------------------------------------------------------------------------
// XLA artifact vs native engine (requires `--features xla` + `make artifacts`)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
mod xla {
    use super::*;
    use migsched::runtime::{artifacts_dir, FragEngine, PjrtRuntime};
    use migsched::sched::{Mfi, MfiXla, Scheduler};

    fn engine_or_skip() -> Option<(PjrtRuntime, FragEngine)> {
        let dir = artifacts_dir();
        if !dir.join("frag.hlo.txt").exists() {
            eprintln!(
                "SKIP: {}/frag.hlo.txt missing — run `make artifacts` first",
                dir.display()
            );
            return None;
        }
        let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
        let engine = FragEngine::load_default(&runtime).expect("loading artifact");
        Some((runtime, engine))
    }

    #[test]
    fn artifact_scores_and_deltas_match_native_exhaustively() {
        let Some((_rt, engine)) = engine_or_skip() else { return };
        let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
        let masks: Vec<u8> = (0..=255u8).collect();
        let batch = engine.evaluate(&masks).expect("evaluate");
        assert_eq!(batch.scores.len(), 256);
        for (i, &mask) in masks.iter().enumerate() {
            let g = GpuState::from_mask(mask);
            assert_eq!(
                batch.scores[i],
                table.score(g) as f32,
                "score mismatch at occ={mask:#010b}"
            );
            for (c, cand) in CANDIDATES.iter().enumerate() {
                let native_feasible = g.fits_at(cand.profile, cand.start);
                assert_eq!(batch.feasible[i][c], native_feasible);
                if native_feasible {
                    assert_eq!(batch.deltas[i][c], table.delta(g, cand.profile, cand.start) as f32);
                } else {
                    assert!(batch.deltas[i][c] > 1e8, "infeasible sentinel missing");
                }
            }
        }
    }

    #[test]
    fn artifact_agrees_with_native_engine_batch() {
        let Some((_rt, engine)) = engine_or_skip() else { return };
        let native = NativeFragEngine::new(&HardwareModel::a100_80gb());
        let mut rng = Rng::new(99);
        let b = engine.batch_size();
        // A cluster 2.5× the artifact batch exercises the chunk+pad path.
        let masks: Vec<u8> =
            (0..b * 5 / 2).map(|_| random_reachable_state(&mut rng).mask()).collect();
        let a = engine.evaluate(&masks).expect("xla evaluate");
        let n = native.evaluate(&masks).expect("native evaluate");
        assert_eq!(a.scores, n.scores);
        assert_eq!(a.feasible, n.feasible);
        for (ra, rn) in a.deltas.iter().zip(&n.deltas) {
            for (c, (&da, &dn)) in ra.iter().zip(rn.iter()).enumerate() {
                if dn == INFEASIBLE_DELTA {
                    assert!(da > 1e8, "cand {c}");
                } else {
                    assert_eq!(da, dn, "cand {c}");
                }
            }
        }
    }

    #[test]
    fn mfi_xla_matches_native_mfi_decisions() {
        let Some((rt, _)) = engine_or_skip() else { return };
        let hw = HardwareModel::a100_80gb();
        let mut native = Mfi::for_hardware(&hw);
        let mut xla = MfiXla::load_default(&rt).expect("loading MfiXla");

        let mut rng = Rng::new(0xABCD);
        for round in 0..30 {
            // Drive BOTH schedulers through an identical random episode.
            let mut cluster = migsched::cluster::Cluster::new(hw.clone(), 6);
            let mut next_id = 0u64;
            for step in 0..80 {
                let p = *rng.choose(&migsched::mig::ALL_PROFILES);
                let a = native.schedule(&cluster, p);
                let b = xla.schedule(&cluster, p);
                assert_eq!(a, b, "round {round} step {step}: decision divergence for {p}");
                if let Some(pl) = a {
                    cluster
                        .allocate(migsched::workload::WorkloadId(next_id), pl)
                        .expect("valid placement");
                    next_id += 1;
                }
                if rng.chance(0.3) && cluster.allocated_workloads() > 0 {
                    let ids: Vec<_> = cluster.allocations().map(|(id, _)| id).collect();
                    cluster.release(*rng.choose(&ids)).unwrap();
                }
            }
        }
    }

    #[test]
    fn frag_engine_metadata() {
        let Some((_rt, engine)) = engine_or_skip() else { return };
        assert!(engine.batch_size() >= 1);
        assert_eq!(engine.rule(), "partial");
    }
}
