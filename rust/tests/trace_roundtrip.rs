//! Trace record/replay determinism across the full stack: generating a
//! trace, saving it, loading it and replaying it must reproduce the
//! original run bit-for-bit, for every scheduler.

use migsched::sched::SchedulerKind;
use migsched::sim::{SimConfig, SimEngine};
use migsched::util::rng::Rng;
use migsched::workload::{Distribution, Trace, WorkloadGenerator};

fn temp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("migsched-it-{}-{name}", std::process::id()))
}

#[test]
fn replay_reproduces_run_for_every_scheduler() {
    let cfg = SimConfig::small(Distribution::Bimodal, 77);
    let engine = SimEngine::new(cfg.clone());
    let capacity = (cfg.num_gpus * cfg.hardware.num_slices()) as u64;
    let generated =
        WorkloadGenerator::new(cfg.distribution.clone()).generate(capacity, &mut Rng::new(77));
    let trace = Trace::from_workloads("roundtrip", capacity, &generated.workloads);

    let path = temp_path("roundtrip.jsonl");
    trace.save(&path).unwrap();
    let loaded = Trace::load(&path).unwrap();
    assert_eq!(loaded, trace);

    for kind in SchedulerKind::all() {
        let mut direct = kind.build(&cfg.hardware);
        let a = engine.replay(&mut *direct, &generated.workloads);
        let mut replayed = kind.build(&cfg.hardware);
        let b = engine.replay_trace(&mut *replayed, &loaded);
        assert_eq!(a.accepted, b.accepted, "{kind}");
        assert_eq!(a.arrived, b.arrived, "{kind}");
        assert_eq!(a.time_avg_frag, b.time_avg_frag, "{kind}");
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.metrics, rb.metrics, "{kind} checkpoint {}", ra.demand);
        }
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn trace_survives_generation_parameters() {
    // Traces generated under every distribution parse back and keep their
    // arrival ordering invariants.
    for (i, dist) in Distribution::paper_set().into_iter().enumerate() {
        let gen = WorkloadGenerator::new(dist.clone()).with_tenants(3);
        let g = gen.generate(400, &mut Rng::new(i as u64 + 1));
        let trace = Trace::from_workloads(dist.name(), 400, &g.workloads);
        let text = trace.render_jsonl();
        let back = Trace::parse_jsonl(&text).unwrap();
        let arrivals = back.arrivals();
        assert_eq!(arrivals, g.workloads, "{dist}");
        assert!(arrivals.windows(2).all(|w| w[0].arrival_slot < w[1].arrival_slot));
    }
}

#[test]
fn corrupted_trace_fails_loudly() {
    let path = temp_path("corrupt.jsonl");
    std::fs::write(&path, "{\"type\":\"header\",\"format\":\"migsched-trace-v1\"}\n").unwrap();
    // Missing capacity_slices → error, not panic.
    assert!(Trace::load(&path).is_err());
    std::fs::remove_file(&path).unwrap();
    assert!(Trace::load(std::path::Path::new("/nonexistent/trace.jsonl")).is_err());
}
