//! Cross-module property-based tests (the `util::check` mini-harness):
//! system-level invariants that hold for ANY workload sequence, scheduler
//! and cluster size.

use migsched::cluster::Cluster;
use migsched::frag::{evaluate_cluster, score_direct_rule, FragScorer, OverlapRule, ScoreTable};
use migsched::mig::{GpuState, HardwareModel, ALL_PROFILES, NUM_SLICES};
use migsched::sched::SchedulerKind;
use migsched::util::check::{assert_close, forall, forall_shrink_vec};
use migsched::util::rng::Rng;
use migsched::workload::{Distribution, WorkloadGenerator, WorkloadId};

/// A random episode: interleaved arrivals (random profiles) and releases.
#[derive(Debug, Clone)]
struct Episode {
    seed: u64,
    gpus: usize,
    steps: usize,
}

fn random_episode(rng: &mut Rng) -> Episode {
    Episode { seed: rng.next_u64(), gpus: 1 + rng.index(8), steps: 20 + rng.index(150) }
}

fn drive(episode: &Episode, kind: SchedulerKind) -> (Cluster, u64, u64) {
    let hw = HardwareModel::a100_80gb();
    let mut rng = Rng::new(episode.seed);
    let mut cluster = Cluster::new(hw.clone(), episode.gpus);
    let mut sched = kind.build(&hw);
    let mut next_id = 0u64;
    let mut accepted = 0u64;
    let mut arrived = 0u64;
    for _ in 0..episode.steps {
        if rng.chance(0.65) {
            arrived += 1;
            let p = *rng.choose(&ALL_PROFILES);
            if let Some(pl) = sched.schedule(&cluster, p) {
                cluster.allocate(WorkloadId(next_id), pl).expect("valid placement");
                accepted += 1;
                next_id += 1;
            }
        } else if cluster.allocated_workloads() > 0 {
            let ids: Vec<_> = cluster.allocations().map(|(id, _)| id).collect();
            cluster.release(*rng.choose(&ids)).unwrap();
        }
    }
    (cluster, accepted, arrived)
}

#[test]
fn prop_no_overlap_ever_and_accounting_consistent() {
    forall("no-overlap", random_episode, |ep| {
        for kind in SchedulerKind::all() {
            let (cluster, accepted, arrived) = drive(ep, kind);
            if accepted > arrived {
                return Err(format!("{kind}: accepted {accepted} > arrived {arrived}"));
            }
            // Per-GPU used slices equals the sum of allocation footprints.
            let mut per_gpu = vec![0u32; cluster.num_gpus()];
            for (_, pl) in cluster.allocations() {
                per_gpu[pl.gpu] += pl.profile.size() as u32;
            }
            for (gpu_id, g) in cluster.gpus().iter().enumerate() {
                if g.used_slices() as u32 != per_gpu[gpu_id] {
                    return Err(format!(
                        "{kind}: gpu {gpu_id} occupancy {} != allocation sum {}",
                        g.used_slices(),
                        per_gpu[gpu_id]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_release_all_restores_empty_cluster() {
    forall("release-restores", random_episode, |ep| {
        let (mut cluster, ..) = drive(ep, SchedulerKind::Mfi);
        let ids: Vec<_> = cluster.allocations().map(|(id, _)| id).collect();
        for id in ids {
            cluster.release(id).map_err(|e| e.to_string())?;
        }
        if cluster.used_slices() != 0 || cluster.active_gpus() != 0 {
            return Err("cluster not empty after releasing everything".into());
        }
        if cluster.gpus().iter().any(|g| !g.is_empty()) {
            return Err("stale occupancy bits".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mfi_completeness() {
    // MFI rejects iff NO feasible placement exists cluster-wide.
    forall("mfi-complete", random_episode, |ep| {
        let hw = HardwareModel::a100_80gb();
        let (cluster, ..) = drive(ep, SchedulerKind::Mfi);
        let mut mfi = SchedulerKind::Mfi.build(&hw);
        for p in ALL_PROFILES {
            let feasible = cluster.gpus().iter().any(|g| g.can_host(p));
            let proposed = mfi.schedule(&cluster, p).is_some();
            if feasible != proposed {
                return Err(format!("{p}: feasible={feasible} proposed={proposed}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mfi_statistically_dominates_ff_under_churn() {
    // MFI is an online greedy policy, so per-sequence dominance is NOT a
    // theorem, and in *static* arrival-only packing (no terminations) MFI
    // and FF are statistically indistinguishable (we measured MFI ~2%
    // BELOW FF on tiny clusters — greedy ΔF-minimization is not a
    // bin-packing heuristic). The paper's claim is specifically about the
    // ONLINE setting, where continuous arrivals+terminations fragment the
    // cluster: there MFI must dominate in aggregate. Assert exactly that,
    // via the paper's own simulation protocol on many seeds.
    use migsched::sim::{SimConfig, SimEngine};
    let hw = HardwareModel::a100_80gb();
    let mut master = Rng::new(0xD0D0);
    let (mut mfi_total, mut ff_total) = (0u64, 0u64);
    for _ in 0..60 {
        let seed = master.next_u64();
        let cfg = SimConfig {
            num_gpus: 6,
            ..SimConfig::paper(Distribution::Uniform, seed)
        };
        let engine = SimEngine::new(cfg);
        let mut mfi = SchedulerKind::Mfi.build(&hw);
        mfi_total += engine.run(&mut *mfi).accepted;
        let mut ff = SchedulerKind::Ff.build(&hw);
        ff_total += engine.run(&mut *ff).accepted;
    }
    assert!(
        mfi_total >= ff_total,
        "MFI accepted {mfi_total} < FF {ff_total} over 60 churn runs"
    );
}

#[test]
fn prop_score_table_equals_direct_for_all_hardware() {
    for hw in [
        HardwareModel::a100_80gb(),
        HardwareModel::a100_40gb(),
        HardwareModel::h100_80gb(),
        HardwareModel::h200_141gb(),
    ] {
        for rule in [OverlapRule::Partial, OverlapRule::Any] {
            let table = ScoreTable::for_hardware_rule(&hw, rule);
            for occ in 0u16..=255 {
                let g = GpuState::from_mask(occ as u8);
                assert_eq!(table.score(g), score_direct_rule(g, &hw, rule));
            }
        }
    }
}

#[test]
fn prop_frag_score_zero_iff_no_partially_blocked_window() {
    let hw = HardwareModel::a100_80gb();
    for occ in 0u16..=255 {
        let g = GpuState::from_mask(occ as u8);
        let score = score_direct_rule(g, &hw, OverlapRule::Partial);
        let has_waste = ALL_PROFILES.iter().any(|&p| {
            p.size() <= g.free_slices()
                && p.starts().iter().any(|&s| {
                    let w = p.mask_at(s);
                    g.mask() & w != 0 && g.mask() & w != w
                })
        });
        assert_eq!(score > 0, has_waste, "occ={occ:#010b}");
    }
}

#[test]
fn prop_generator_capacity_invariant() {
    forall(
        "generator-saturates",
        |rng| (rng.next_u64(), 1 + rng.index(4)),
        |&(seed, scale)| {
            let capacity = 200 * scale as u64;
            for dist in Distribution::paper_set() {
                let gen = WorkloadGenerator::new(dist.clone());
                let g = gen.generate(capacity, &mut Rng::new(seed));
                let total: u64 = g.workloads.iter().map(|w| w.slices() as u64).sum();
                if total < capacity {
                    return Err(format!("{dist}: total {total} < capacity {capacity}"));
                }
                let last = g.workloads.last().unwrap().slices() as u64;
                if total - last >= capacity {
                    return Err(format!("{dist}: over-generated past saturation"));
                }
                for w in &g.workloads {
                    if w.duration_slots == 0 || w.duration_slots > g.horizon {
                        return Err(format!("{dist}: duration {} out of [1, T]", w.duration_slots));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mean_score_linear_in_cluster() {
    // mean_score over the concatenation of clusters == weighted mean —
    // sanity for the Fig. 6 metric aggregation.
    let table = ScoreTable::for_hardware(&HardwareModel::a100_80gb());
    forall(
        "mean-score-linearity",
        |rng| {
            let a: Vec<u8> = (0..1 + rng.index(6)).map(|_| rng.next_u64() as u8).collect();
            let b: Vec<u8> = (0..1 + rng.index(6)).map(|_| rng.next_u64() as u8).collect();
            (a, b)
        },
        |(a, b)| {
            let ga: Vec<GpuState> = a.iter().map(|&m| GpuState::from_mask(m)).collect();
            let gb: Vec<GpuState> = b.iter().map(|&m| GpuState::from_mask(m)).collect();
            let all: Vec<GpuState> = ga.iter().chain(gb.iter()).copied().collect();
            let expect = (table.mean_score(&ga) * ga.len() as f64
                + table.mean_score(&gb) * gb.len() as f64)
                / all.len() as f64;
            assert_close(table.mean_score(&all), expect, 1e-12, "linearity");
            Ok(())
        },
    );
}

#[test]
fn prop_mfi_placement_is_exhaustive_argmin() {
    // Algorithm 2 correctness: the placement MFI commits must equal the
    // exhaustive argmin of ΔF over ALL feasible (gpu, index) candidates,
    // with the documented deterministic tie-break (lowest ΔF, then lowest
    // GPU id, then lowest anchor index). Cases are raw occupancy-mask
    // vectors — one u64 per GPU, masked to 8 bits — so shrunk
    // counterexamples are minimal occupancy patterns, not episodes.
    let hw = HardwareModel::a100_80gb();
    let table = ScoreTable::for_hardware(&hw);
    forall_shrink_vec(
        "mfi-argmin-exhaustive",
        |rng| (0..1 + rng.index(8)).map(|_| rng.next_u64() & 0xFF).collect(),
        |masks| {
            let gpus: Vec<GpuState> =
                masks.iter().map(|&m| GpuState::from_mask((m & 0xFF) as u8)).collect();
            for p in ALL_PROFILES {
                let got = evaluate_cluster(&table, &gpus, p);
                // Exhaustive reference: every (gpu, anchor) pair, ordered
                // lexicographically by (ΔF, gpu, anchor).
                let mut best: Option<(i32, usize, u8)> = None;
                for (gid, g) in gpus.iter().enumerate() {
                    for &s in p.starts() {
                        if !g.fits_at(p, s) {
                            continue;
                        }
                        let d = table.delta(*g, p, s);
                        if best.is_none() || (d, gid, s) < best.unwrap() {
                            best = Some((d, gid, s));
                        }
                    }
                }
                match (got, best) {
                    (None, None) => {}
                    (Some(pl), Some((d, gid, s))) => {
                        if (pl.gpu, pl.index) != (gid, s) {
                            return Err(format!(
                                "{p}: MFI chose gpu {} index {}, exhaustive argmin is \
                                 gpu {gid} index {s} (ΔF {d})",
                                pl.gpu, pl.index
                            ));
                        }
                        if pl.profile != p {
                            return Err(format!("{p}: placement changed profile to {}", pl.profile));
                        }
                    }
                    (a, b) => {
                        return Err(format!("{p}: feasibility disagreement {a:?} vs {b:?}"))
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replay_with_defrag_conserves_and_index_stays_identical() {
    // Continuous defrag must never break counter conservation, never
    // create or lose allocations, and — because migrations flow through
    // the cluster change log — must leave MFI and MFI-IDX
    // placement-identical on any stream, cadence and budget.
    use migsched::defrag::DefragPolicy;
    use migsched::sim::replay::{self, ReplayConfig};
    use migsched::workload::Trace;
    forall(
        "replay-defrag",
        |rng| {
            (
                rng.next_u64(),
                2 + rng.index(5),            // gpus
                2 + rng.index(10) as u64,    // sweep cadence
                rng.index(4) as u64 * 40,    // cost budget (0 = unlimited)
            )
        },
        |&(seed, gpus, every, budget)| {
            let gen = WorkloadGenerator::new(Distribution::Bimodal).with_tenants(5);
            let ws = gen.generate_stream(120, 0.6, 25, &mut Rng::new(seed));
            let trace = Trace::from_workloads("prop defrag", 64, &ws);
            let hw = HardwareModel::a100_80gb();
            let cfg = ReplayConfig {
                defrag: Some(
                    DefragPolicy::every(every).with_max_moves(8).with_cost_budget(budget),
                ),
                ..ReplayConfig::new(gpus)
            };
            let mut mfi = SchedulerKind::Mfi.build(&hw);
            let ra = replay::run(&trace, &mut *mfi, &cfg);
            if !ra.conserved() {
                return Err(format!(
                    "MFI: arrived {} != accepted {} + rejected {}",
                    ra.arrived, ra.accepted, ra.rejected
                ));
            }
            let mut idx = SchedulerKind::MfiIdx.build(&hw);
            let rb = replay::run(&trace, &mut *idx, &cfg);
            if (ra.accepted, ra.rejected, ra.migrations, ra.migrated_bytes)
                != (rb.accepted, rb.rejected, rb.migrations, rb.migrated_bytes)
            {
                return Err(format!(
                    "MFI vs MFI-IDX diverged under defrag: \
                     ({}, {}, {}, {}) vs ({}, {}, {}, {})",
                    ra.accepted,
                    ra.rejected,
                    ra.migrations,
                    ra.migrated_bytes,
                    rb.accepted,
                    rb.rejected,
                    rb.migrations,
                    rb.migrated_bytes
                ));
            }
            if ra.time_avg_frag != rb.time_avg_frag {
                return Err("frag trajectories diverged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_slices_conserved_during_sim() {
    // At every checkpoint: utilization × capacity == Σ profile sizes of
    // currently allocated workloads ≤ capacity.
    use migsched::sim::{SimConfig, SimEngine};
    forall(
        "sim-conservation",
        |rng| rng.next_u64(),
        |&seed| {
            let cfg = SimConfig::small(Distribution::Uniform, seed);
            let engine = SimEngine::new(cfg.clone());
            let hw = cfg.hardware.clone();
            for kind in [SchedulerKind::Mfi, SchedulerKind::Ff, SchedulerKind::WfBi] {
                let mut sched = kind.build(&hw);
                let result = engine.run(&mut *sched);
                let capacity = (cfg.num_gpus * NUM_SLICES) as f64;
                for rec in &result.records {
                    let used = rec.metrics.utilization * capacity;
                    if used < -1e-9 || used > capacity + 1e-9 {
                        return Err(format!("{kind}: used {used} out of range"));
                    }
                    if rec.metrics.active_gpus > cfg.num_gpus {
                        return Err(format!("{kind}: active GPUs exceed cluster"));
                    }
                }
            }
            Ok(())
        },
    );
}
