//! Concurrency soak: client threads hammer submit/release/tick against a
//! live multi-shard daemon over real sockets, then the counters must
//! conserve exactly and the fleet must drain back to blank.
//!
//! Counter semantics under test (see README "Sharded serving daemon"):
//!   arrived_total   = accepted_total + rejections (409s)
//!   allocated       = accepted_total − released_total − expired_total
//!   released_total  counts explicit DELETEs only
//!   expired_total   counts lease expiries via /v1/tick only

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;

#[test]
fn multi_shard_soak_conserves_counters_and_drains() {
    let n_threads: usize = 6;
    let per_thread: usize = 40;
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 12,
        workers: 8,
        shards: 4,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    let accepted = Arc::new(AtomicU64::new(0));
    let rejected = Arc::new(AtomicU64::new(0));

    let threads: Vec<_> = (0..n_threads)
        .map(|t| {
            let addr = addr.clone();
            let accepted = Arc::clone(&accepted);
            let rejected = Arc::clone(&rejected);
            std::thread::spawn(move || {
                let client = HttpClient::new(&addr);
                let profiles = ["1g.10gb", "2g.20gb", "3g.40gb", "1g.20gb"];
                let mut live: Vec<u64> = Vec::new();
                for i in 0..per_thread {
                    let profile = profiles[(t + i) % profiles.len()];
                    let tenant = (t * 31 + i % 5) as u64;
                    let mut body =
                        Json::obj().with("profile", profile).with("tenant", tenant);
                    if i % 3 == 0 {
                        body = body.with("duration_slots", 2 + (i % 4) as u64);
                    }
                    let r = client.post_json("/v1/workloads", &body).expect("submit");
                    match r.status {
                        201 => {
                            accepted.fetch_add(1, Ordering::Relaxed);
                            live.push(r.json().unwrap().req_u64("id").unwrap());
                        }
                        409 => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("unexpected submit status {other}: {}", r.body),
                    }
                    // Churn: release one of ours now and then. It may have
                    // expired under a concurrent tick — then 404 is the
                    // correct answer and expired_total took the count.
                    if i % 4 == 3 {
                        if let Some(id) = live.pop() {
                            let r = client
                                .delete(&format!("/v1/workloads/{id}"))
                                .expect("release");
                            assert!(
                                r.status == 200 || r.status == 404,
                                "unexpected delete status {}: {}",
                                r.status,
                                r.body
                            );
                        }
                    }
                    if i % 16 == 7 {
                        let r = client
                            .post_json("/v1/tick", &Json::obj().with("slots", 1u64))
                            .expect("tick");
                        assert_eq!(r.status, 200);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }

    let client = HttpClient::new(&addr);
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    let arrived = stats.req_u64("arrived_total").unwrap();
    let acc = stats.req_u64("accepted_total").unwrap();
    let rel = stats.req_u64("released_total").unwrap();
    let exp = stats.req_u64("expired_total").unwrap();
    let allocated = stats.req_u64("allocated_workloads").unwrap();
    assert_eq!(stats.req_u64("shards").unwrap(), 4);
    assert_eq!(arrived, (n_threads * per_thread) as u64, "every submit was counted");
    assert_eq!(acc, accepted.load(Ordering::Relaxed), "server/client accepted agree");
    assert_eq!(
        arrived,
        acc + rejected.load(Ordering::Relaxed),
        "arrived = accepted + rejected"
    );
    assert_eq!(allocated, acc - rel - exp, "allocated = accepted - released - expired");

    // Full drain: everything the fleet still hosts releases cleanly.
    let snap = client.get("/v1/cluster").unwrap().json().unwrap();
    let allocs = snap.get("allocations").unwrap().as_arr().unwrap();
    assert_eq!(allocs.len() as u64, allocated, "snapshot agrees with stats");
    for a in allocs {
        let id = a.req_u64("workload").unwrap();
        let r = client.delete(&format!("/v1/workloads/{id}")).unwrap();
        assert_eq!(r.status, 200, "draining {id}: {}", r.body);
    }

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("allocated_workloads").unwrap(), 0);
    assert_eq!(stats.get("utilization").unwrap().as_f64(), Some(0.0));
    assert_eq!(
        stats.req_u64("accepted_total").unwrap(),
        stats.req_u64("released_total").unwrap() + stats.req_u64("expired_total").unwrap(),
        "after the drain every acceptance was released or expired"
    );
    // Every GPU is blank again.
    let snap = client.get("/v1/cluster").unwrap().json().unwrap();
    for mask in snap.get("gpu_masks").unwrap().as_arr().unwrap() {
        assert_eq!(mask.as_u64(), Some(0), "drained fleet has empty occupancy");
    }
    handle.shutdown();
}
