//! Concurrency soak: client threads hammer submit/release/tick against a
//! live multi-shard daemon over real sockets, then the counters must
//! conserve exactly and the fleet must drain back to blank.
//!
//! Counter semantics under test (see README "Sharded serving daemon"):
//!   arrived_total   = accepted_total + rejections (409s)
//!   allocated       = accepted_total − released_total − expired_total
//!   released_total  counts explicit DELETEs only
//!   expired_total   counts lease expiries via /v1/tick only
//!
//! A scraper thread hits `GET /metrics` throughout the run: every
//! mid-flight snapshot must satisfy the scrape-time invariants (cumulative
//! buckets, requests ≥ responses, the per-shard counter identity), and
//! after the drain the HTTP counters must converge to exact conservation.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use migsched::server::{Daemon, DaemonConfig, HttpClient, ServeModel};
use migsched::util::json::Json;

/// Pull one value out of an exposition: the sum over all samples of
/// `family` (skips `# ` comments; histogram series excluded by the
/// `_bucket`/`_sum`/`_count` suffix check).
fn family_sum(text: &str, family: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let (name_labels, value) = l.rsplit_once(' ')?;
            let name = name_labels.split('{').next().unwrap();
            (name == family).then(|| value.parse::<f64>().unwrap())
        })
        .sum()
}

/// Scrape-time invariants that must hold in ANY snapshot, even one taken
/// mid-burst with all client threads live.
fn check_snapshot(text: &str) {
    // Cumulative buckets never decrease within a series, and the +Inf
    // bucket equals the series' _count (bucket lines for one series are
    // consecutive, finite bounds first, then +Inf, then _sum and _count).
    let mut prev: Option<(String, f64)> = None; // (series prefix, last value)
    let mut pending_inf: Option<(String, f64)> = None; // (count name+labels, +Inf value)
    for line in text.lines().filter(|l| !l.starts_with('#')) {
        let (name_labels, value) = line.rsplit_once(' ').expect("sample line");
        let value: f64 = value.parse().unwrap_or_else(|_| panic!("bad value: {line}"));
        if let Some((prefix, _)) = name_labels.split_once("le=\"") {
            let series = prefix.to_string();
            if let Some((last_series, last_v)) = &prev {
                if *last_series == series {
                    assert!(value >= *last_v, "bucket decreased: {line}");
                }
            }
            if name_labels.contains("le=\"+Inf\"") {
                // Derive the matching _count sample name for this series.
                // Split at the FIRST '{' (label values like "/{id}" may
                // contain braces of their own), keep the other labels.
                let (bucket_name, labels) = series.split_once('{').expect("label brace");
                let base = bucket_name.strip_suffix("_bucket").expect("bucket suffix");
                let labels = labels.trim_end_matches(',');
                let count_name = if labels.is_empty() {
                    format!("{base}_count")
                } else {
                    format!("{base}_count{{{labels}}}")
                };
                pending_inf = Some((count_name, value));
                prev = None;
            } else {
                prev = Some((series, value));
            }
            continue;
        }
        if let Some((count_name, inf_v)) = &pending_inf {
            if name_labels == count_name {
                assert_eq!(value, *inf_v, "+Inf bucket != _count: {line}");
                pending_inf = None;
            }
        }
    }
    assert!(pending_inf.is_none(), "+Inf bucket without a matching _count");

    // A request is counted at dispatch, its response only after the bytes
    // hit the socket — no snapshot may ever see responses ahead.
    let requests = family_sum(text, "migsched_http_requests_total");
    let responses = family_sum(text, "migsched_http_responses_total");
    assert!(
        requests >= responses,
        "snapshot saw responses ({responses}) ahead of requests ({requests})"
    );

    // Per-shard identity, preserved by summation because each shard's
    // counters are sampled under its own lock.
    let accepted = family_sum(text, "migsched_accepted_total");
    let released = family_sum(text, "migsched_released_total");
    let expired = family_sum(text, "migsched_expired_total");
    let allocated = family_sum(text, "migsched_allocated_workloads");
    assert_eq!(
        allocated,
        accepted - released - expired,
        "allocated = accepted - released - expired must hold in every snapshot"
    );
    assert!(family_sum(text, "migsched_submits_total") >= accepted);
}

#[test]
fn multi_shard_soak_conserves_counters_and_drains() {
    soak(ServeModel::default());
}

#[test]
fn multi_shard_soak_on_the_threadpool_model() {
    // The blocking fallback must satisfy the same invariants under the
    // same concurrent load as the default event-loop model.
    soak(ServeModel::Threadpool);
}

fn soak(model: ServeModel) {
    let n_threads: usize = 6;
    let per_thread: usize = 40;
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 12,
        workers: 8,
        shards: 4,
        model,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    let accepted = Arc::new(AtomicU64::new(0));
    let rejected = Arc::new(AtomicU64::new(0));

    // Concurrent scraper: every snapshot taken while the 6 client threads
    // hammer the daemon must satisfy the scrape-time invariants.
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || -> usize {
            let client = HttpClient::new(&addr);
            let mut scrapes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let r = client.get("/metrics").expect("scrape");
                assert_eq!(r.status, 200);
                check_snapshot(&r.body);
                scrapes += 1;
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            scrapes
        })
    };

    let threads: Vec<_> = (0..n_threads)
        .map(|t| {
            let addr = addr.clone();
            let accepted = Arc::clone(&accepted);
            let rejected = Arc::clone(&rejected);
            std::thread::spawn(move || {
                let client = HttpClient::new(&addr);
                let profiles = ["1g.10gb", "2g.20gb", "3g.40gb", "1g.20gb"];
                let mut live: Vec<u64> = Vec::new();
                for i in 0..per_thread {
                    let profile = profiles[(t + i) % profiles.len()];
                    let tenant = (t * 31 + i % 5) as u64;
                    let mut body =
                        Json::obj().with("profile", profile).with("tenant", tenant);
                    if i % 3 == 0 {
                        body = body.with("duration_slots", 2 + (i % 4) as u64);
                    }
                    let r = client.post_json("/v1/workloads", &body).expect("submit");
                    match r.status {
                        201 => {
                            accepted.fetch_add(1, Ordering::Relaxed);
                            live.push(r.json().unwrap().req_u64("id").unwrap());
                        }
                        409 => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        other => panic!("unexpected submit status {other}: {}", r.body),
                    }
                    // Churn: release one of ours now and then. It may have
                    // expired under a concurrent tick — then 404 is the
                    // correct answer and expired_total took the count.
                    if i % 4 == 3 {
                        if let Some(id) = live.pop() {
                            let r = client
                                .delete(&format!("/v1/workloads/{id}"))
                                .expect("release");
                            assert!(
                                r.status == 200 || r.status == 404,
                                "unexpected delete status {}: {}",
                                r.status,
                                r.body
                            );
                        }
                    }
                    if i % 16 == 7 {
                        let r = client
                            .post_json("/v1/tick", &Json::obj().with("slots", 1u64))
                            .expect("tick");
                        assert_eq!(r.status, 200);
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().expect("scraper invariants held");
    assert!(scrapes > 0, "the scraper observed at least one mid-run snapshot");

    let client = HttpClient::new(&addr);
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    let arrived = stats.req_u64("arrived_total").unwrap();
    let acc = stats.req_u64("accepted_total").unwrap();
    let rel = stats.req_u64("released_total").unwrap();
    let exp = stats.req_u64("expired_total").unwrap();
    let allocated = stats.req_u64("allocated_workloads").unwrap();
    assert_eq!(stats.req_u64("shards").unwrap(), 4);
    assert_eq!(arrived, (n_threads * per_thread) as u64, "every submit was counted");
    assert_eq!(acc, accepted.load(Ordering::Relaxed), "server/client accepted agree");
    assert_eq!(
        arrived,
        acc + rejected.load(Ordering::Relaxed),
        "arrived = accepted + rejected"
    );
    assert_eq!(allocated, acc - rel - exp, "allocated = accepted - released - expired");

    // Full drain: everything the fleet still hosts releases cleanly.
    let snap = client.get("/v1/cluster").unwrap().json().unwrap();
    let allocs = snap.get("allocations").unwrap().as_arr().unwrap();
    assert_eq!(allocs.len() as u64, allocated, "snapshot agrees with stats");
    for a in allocs {
        let id = a.req_u64("workload").unwrap();
        let r = client.delete(&format!("/v1/workloads/{id}")).unwrap();
        assert_eq!(r.status, 200, "draining {id}: {}", r.body);
    }

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("allocated_workloads").unwrap(), 0);
    assert_eq!(stats.get("utilization").unwrap().as_f64(), Some(0.0));
    assert_eq!(
        stats.req_u64("accepted_total").unwrap(),
        stats.req_u64("released_total").unwrap() + stats.req_u64("expired_total").unwrap(),
        "after the drain every acceptance was released or expired"
    );
    // Every GPU is blank again.
    let snap = client.get("/v1/cluster").unwrap().json().unwrap();
    for mask in snap.get("gpu_masks").unwrap().as_arr().unwrap() {
        assert_eq!(mask.as_u64(), Some(0), "drained fleet has empty occupancy");
    }

    // After the drain the metric counters converge to exact conservation:
    // requests == responses (only this client's in-flight window can lag,
    // so poll briefly) and the exposition agrees with /v1/stats.
    let arrived_total = client
        .get("/v1/stats")
        .unwrap()
        .json()
        .unwrap()
        .req_u64("arrived_total")
        .unwrap() as f64;
    let mut converged = false;
    for _ in 0..100 {
        let body = client.get("/metrics").expect("scrape").body;
        check_snapshot(&body);
        assert_eq!(
            family_sum(&body, "migsched_submits_total"),
            arrived_total,
            "exposition submits_total tracks /v1/stats arrived_total"
        );
        assert_eq!(family_sum(&body, "migsched_allocated_workloads"), 0.0);
        let requests = family_sum(&body, "migsched_http_requests_total");
        let responses = family_sum(&body, "migsched_http_responses_total");
        if requests == responses {
            converged = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(converged, "requests never converged to responses after the drain");
    handle.shutdown();
}
