//! End-to-end simulation: a scaled-down version of the paper's full
//! evaluation, asserting the *shape* of the published results —
//! who wins, in which regime — rather than absolute numbers.

use migsched::sched::SchedulerKind;
use migsched::sim::experiment::{run_sweep, ExperimentConfig};
use migsched::sim::{fig4_report, fig5_report, fig6_report};
use migsched::workload::Distribution;

fn sweep(runs: usize, gpus: usize) -> migsched::sim::experiment::SweepResult {
    run_sweep(&ExperimentConfig {
        num_gpus: gpus,
        runs,
        schemes: SchedulerKind::paper_set().to_vec(),
        distributions: Distribution::paper_set().to_vec(),
        checkpoints: vec![0.25, 0.5, 0.85, 1.0],
        threads: 0,
        ..ExperimentConfig::paper()
    })
}

#[test]
fn paper_headline_shape_holds() {
    // 30 seeds × M=25 is enough for the ordering to be stable.
    let sweep = sweep(30, 25);
    let idx85 = sweep.checkpoint_index(0.85);

    for dist in Distribution::paper_set() {
        let mfi = sweep.series_for(SchedulerKind::Mfi, &dist).unwrap();
        let mfi_acc = mfi.checkpoints[idx85].acceptance_rate.mean();
        // 1. MFI sustains near-perfect acceptance under heavy load.
        assert!(
            mfi_acc > 0.95,
            "{dist}: MFI acceptance at 85% demand should be ~1, got {mfi_acc:.4}"
        );
        // 2. MFI beats every baseline on accepted workloads at 85%.
        for baseline in [
            SchedulerKind::Ff,
            SchedulerKind::Rr,
            SchedulerKind::BfBi,
            SchedulerKind::WfBi,
        ] {
            let b = sweep.series_for(baseline, &dist).unwrap();
            let b_acc = b.checkpoints[idx85].accepted_workloads.mean();
            let m_acc = mfi.checkpoints[idx85].accepted_workloads.mean();
            assert!(
                m_acc >= b_acc - 1e-9,
                "{dist}: MFI accepted {m_acc:.1} < {baseline} {b_acc:.1} at 85%"
            );
        }
        // 3. MFI's fragmentation severity is the lowest (Fig. 6) — within
        // a small tolerance on the scaled-down cluster, since under
        // skew-small all schemes produce near-zero fragmentation and the
        // ordering of tiny values is noisy at 30 seeds.
        let floor = [SchedulerKind::Ff, SchedulerKind::Rr, SchedulerKind::BfBi,
                     SchedulerKind::WfBi]
            .iter()
            .map(|&b| sweep.series_for(b, &dist).unwrap().time_avg_frag.mean())
            .fold(f64::INFINITY, f64::min);
        assert!(
            mfi.time_avg_frag.mean() <= floor * 1.10 + 0.05,
            "{dist}: MFI frag {:.3} not within 10% of best baseline {:.3}",
            mfi.time_avg_frag.mean(),
            floor
        );
    }
}

#[test]
fn heavy_load_gap_is_material_under_uniform() {
    // The paper reports ~10% more scheduled workloads in heavy load
    // (average over the baselines). We assert a >=8% gap vs the baseline
    // mean and a non-negative gap vs the best single baseline.
    let sweep = sweep(30, 25);
    let idx = sweep.checkpoint_index(1.0);
    let dist = Distribution::Uniform;
    let mfi = sweep
        .series_for(SchedulerKind::Mfi, &dist)
        .unwrap()
        .checkpoints[idx]
        .accepted_workloads
        .mean();
    let baselines: Vec<f64> = [SchedulerKind::Ff, SchedulerKind::Rr, SchedulerKind::BfBi,
                               SchedulerKind::WfBi]
        .iter()
        .map(|&k| sweep.series_for(k, &dist).unwrap().checkpoints[idx].accepted_workloads.mean())
        .collect();
    let mean = baselines.iter().sum::<f64>() / baselines.len() as f64;
    let best = baselines.iter().cloned().fold(0.0, f64::max);
    assert!(
        mfi > mean * 1.08,
        "MFI {mfi:.1} should beat the baseline mean {mean:.1} by >=8% (paper: ~10%)"
    );
    assert!(
        mfi >= best * 0.999,
        "MFI {mfi:.1} should be at least the best baseline {best:.1}"
    );
}

#[test]
fn low_load_acceptance_shape() {
    // Paper Fig. 4b at low demand: the spreading schemes (RR, WF-BI) and
    // MFI accept essentially everything; the packing schemes (FF, BF-BI)
    // already reject some requests — their committed frontier GPU is the
    // one most likely to have blocked anchors (the Fig. 3 mechanism), and
    // MIG-awareness (BF-BI's best-index rule) softens but does not remove
    // the effect.
    let sweep = sweep(15, 25);
    let idx = sweep.checkpoint_index(0.25);
    let acc = |k: SchedulerKind| {
        sweep
            .series_for(k, &Distribution::Uniform)
            .unwrap()
            .checkpoints[idx]
            .acceptance_rate
            .mean()
    };
    for kind in [SchedulerKind::Mfi, SchedulerKind::Rr, SchedulerKind::WfBi] {
        assert!(acc(kind) > 0.95, "{kind} acceptance at 25% demand is {:.3}", acc(kind));
    }
    for kind in [SchedulerKind::Ff, SchedulerKind::BfBi] {
        assert!(
            acc(kind) > 0.70,
            "{kind} acceptance at 25% demand is {:.3}",
            acc(kind)
        );
    }
    // MIG-aware beats its agnostic counterpart (paper Section VI).
    assert!(acc(SchedulerKind::BfBi) > acc(SchedulerKind::Ff));
}

#[test]
fn rr_deteriorates_with_load() {
    // Paper: RR's acceptance "sharply deteriorates as the cluster
    // utilization increases".
    let sweep = sweep(20, 25);
    let lo = sweep.checkpoint_index(0.25);
    let hi = sweep.checkpoint_index(1.0);
    let s = sweep.series_for(SchedulerKind::Rr, &Distribution::Uniform).unwrap();
    let acc_lo = s.checkpoints[lo].acceptance_rate.mean();
    let acc_hi = s.checkpoints[hi].acceptance_rate.mean();
    assert!(acc_lo > 0.97, "RR near-perfect at low load, got {acc_lo:.3}");
    assert!(
        acc_hi < acc_lo - 0.04,
        "RR should degrade materially: {acc_lo:.3} -> {acc_hi:.3}"
    );
}

#[test]
fn reports_render_without_panic_and_mention_all_schemes() {
    let sweep = sweep(6, 16);
    let f4 = fig4_report(&sweep, &Distribution::Uniform).render();
    let f5 = fig5_report(&sweep, 0.85).render();
    let f6 = fig6_report(&sweep).render();
    for text in [&f4, &f5, &f6] {
        for kind in SchedulerKind::paper_set() {
            assert!(text.contains(kind.name()), "missing {kind} in report");
        }
    }
    assert!(f4.contains("Fig. 4d"));
    assert!(f5.contains("85%"));
    assert!(f6.contains("fragmentation"));
}

#[test]
fn periodic_defrag_extension_helps_baselines() {
    // The paper's future-work extension (rescheduling): periodic
    // migration should recover some of the acceptance a commitment-based
    // baseline loses to fragmentation, and never hurt MFI.
    use migsched::sim::{SimConfig, SimEngine};
    let hw = migsched::mig::HardwareModel::a100_80gb();
    let mut plain_acc = 0.0;
    let mut defrag_acc = 0.0;
    let mut plain_frag = 0.0;
    let mut defrag_frag = 0.0;
    let mut migrations = 0u64;
    let seeds = [3u64, 5, 8, 13, 21, 34, 55, 89];
    for &seed in &seeds {
        let base = SimConfig { num_gpus: 25, ..SimConfig::paper(Distribution::Uniform, seed) };
        let engine = SimEngine::new(base.clone());
        let mut ff = SchedulerKind::Ff.build(&hw);
        let r = engine.run(&mut *ff);
        plain_acc += r.acceptance_rate();
        plain_frag += r.time_avg_frag;

        let engine = SimEngine::new(base.with_defrag(5, 8));
        let mut ff = SchedulerKind::Ff.build(&hw);
        let r = engine.run(&mut *ff);
        defrag_acc += r.acceptance_rate();
        defrag_frag += r.time_avg_frag;
        migrations += r.migrations;
    }
    assert!(migrations > 0, "defragmenter should find migrations");
    // The planner's direct objective: strictly lower fragmentation.
    assert!(
        defrag_frag < plain_frag,
        "defrag should reduce time-avg fragmentation: {defrag_frag:.3} vs {plain_frag:.3}"
    );
    // Acceptance must not regress materially (FF's losses are mostly its
    // commitment policy, which migration cannot fix — parity is expected).
    assert!(
        defrag_acc >= plain_acc * 0.99,
        "defrag must not hurt FF acceptance: {defrag_acc:.3} vs {plain_acc:.3}"
    );
}

#[test]
fn skew_small_hurts_bin_packing_most() {
    // Paper Section VI: under skew-small, bin-packing (FF/BF-BI) suffers
    // the most from fragmentation; MFI's gap vs BF-BI should be at least
    // as large as under skew-big (where placements are forced anyway).
    let sweep = sweep(30, 25);
    let idx = sweep.checkpoint_index(0.85);
    let gap = |dist: &Distribution| {
        let mfi = sweep.series_for(SchedulerKind::Mfi, dist).unwrap().checkpoints[idx]
            .acceptance_rate
            .mean();
        let bf = sweep.series_for(SchedulerKind::BfBi, dist).unwrap().checkpoints[idx]
            .acceptance_rate
            .mean();
        mfi - bf
    };
    let small_gap = gap(&Distribution::SkewSmall);
    let big_gap = gap(&Distribution::SkewBig);
    assert!(
        small_gap >= big_gap - 0.02,
        "skew-small gap {small_gap:.4} should be >= skew-big gap {big_gap:.4}"
    );
}
