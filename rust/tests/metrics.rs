//! `GET /metrics` contract over a live socket: the exposition must be
//! well-formed Prometheus text format (every sample preceded by exactly
//! one `# TYPE`, no duplicate families, cumulative buckets, `le="+Inf"`
//! equal to `_count`), and the re-exported cluster counters must agree
//! sample-for-sample with `/v1/stats` after a scripted
//! submit/release/tick sequence.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use migsched::sched::SchedulerKind;
use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;

/// Raw HTTP GET that keeps the response headers ([`HttpClient`] hides
/// them, and the exposition `Content-Type` is part of the contract).
fn raw_get(addr: &str, path: &str) -> (u16, BTreeMap<String, String>, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(stream, "GET {path} HTTP/1.1\r\nHost: test\r\nConnection: close\r\n\r\n")
        .expect("send request");
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("utf-8 response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let mut lines = head.lines();
    let status: u16 = lines
        .next()
        .expect("status line")
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let headers = lines
        .map(|l| {
            let (k, v) = l.split_once(':').expect("header line");
            (k.trim().to_ascii_lowercase(), v.trim().to_string())
        })
        .collect();
    (status, headers, body.to_string())
}

/// One parsed sample: metric name (with `_bucket`/`_sum`/`_count` suffix
/// intact), its label pairs, and the value.
#[derive(Debug, Clone)]
struct Sample {
    name: String,
    labels: Vec<(String, String)>,
    value: f64,
}

impl Sample {
    fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// The label set minus `le`, as a grouping key for bucket series.
    fn series_key(&self) -> String {
        let mut parts: Vec<String> = self
            .labels
            .iter()
            .filter(|(k, _)| k != "le")
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        parts.sort();
        parts.join(",")
    }
}

/// Parse + lint the exposition. Panics (with context) on any format
/// violation; returns samples grouped by family name.
fn lint_exposition(text: &str) -> BTreeMap<String, (String, Vec<Sample>)> {
    // family name -> (kind, samples)
    let mut families: BTreeMap<String, (String, Vec<Sample>)> = BTreeMap::new();
    let mut order: Vec<String> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("family name").to_string();
            let kind = it.next().expect("family kind").to_string();
            assert!(
                !families.contains_key(&name),
                "duplicate # TYPE for family {name}"
            );
            families.insert(name.clone(), (kind, Vec::new()));
            order.push(name);
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or comment
        }
        // Sample line: name[{labels}] value
        let (name_labels, value) = line.rsplit_once(' ').expect("sample has a value");
        let value: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        let (name, labels) = match name_labels.split_once('{') {
            Some((n, rest)) => {
                let inner = rest.strip_suffix('}').expect("closing brace");
                let labels = inner
                    .split(',')
                    .map(|pair| {
                        let (k, v) = pair.split_once('=').expect("label pair");
                        let v = v
                            .strip_prefix('"')
                            .and_then(|v| v.strip_suffix('"'))
                            .expect("quoted label value");
                        (k.to_string(), v.to_string())
                    })
                    .collect();
                (n.to_string(), labels)
            }
            None => (name_labels.to_string(), Vec::new()),
        };
        // Resolve the family this sample belongs to: exact name for
        // counters/gauges, stripped suffix for histogram series. The
        // family must already be declared — that is the "every sample is
        // preceded by its # TYPE" rule.
        let family = if families.contains_key(&name) {
            name.clone()
        } else {
            ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|suffix| {
                    let base = name.strip_suffix(suffix)?;
                    match families.get(base) {
                        Some((kind, _)) if kind == "histogram" => Some(base.to_string()),
                        _ => None,
                    }
                })
                .unwrap_or_else(|| panic!("sample {name} has no preceding # TYPE"))
        };
        families.get_mut(&family).unwrap().1.push(Sample { name, labels, value });
    }

    // Histogram invariants per (family, label set): buckets cumulative in
    // `le` order, `+Inf` bucket == `_count`, and an empty series has zero
    // sum.
    for (family, (kind, samples)) in &families {
        if kind != "histogram" {
            continue;
        }
        let mut buckets: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
        let mut counts: BTreeMap<String, f64> = BTreeMap::new();
        let mut sums: BTreeMap<String, f64> = BTreeMap::new();
        for s in samples {
            if s.name.ends_with("_bucket") {
                let le = s.label("le").expect("bucket has le");
                let le = if le == "+Inf" { f64::INFINITY } else { le.parse().expect("le bound") };
                buckets.entry(s.series_key()).or_default().push((le, s.value));
            } else if s.name.ends_with("_count") {
                counts.insert(s.series_key(), s.value);
            } else if s.name.ends_with("_sum") {
                sums.insert(s.series_key(), s.value);
            } else {
                panic!("unexpected sample {} in histogram {family}", s.name);
            }
        }
        for (series, series_buckets) in &buckets {
            let mut last_le = f64::NEG_INFINITY;
            let mut last_v = 0.0;
            for &(le, v) in series_buckets {
                assert!(le > last_le, "{family}{{{series}}}: le bounds must increase");
                assert!(
                    v >= last_v,
                    "{family}{{{series}}}: bucket at le={le} decreased ({v} < {last_v})"
                );
                (last_le, last_v) = (le, v);
            }
            let (inf_le, inf_v) = *series_buckets.last().unwrap();
            assert!(inf_le.is_infinite(), "{family}{{{series}}}: missing le=\"+Inf\"");
            let count = counts.get(series).unwrap_or_else(|| {
                panic!("{family}{{{series}}}: buckets without a _count sample")
            });
            assert_eq!(inf_v, *count, "{family}{{{series}}}: +Inf bucket != _count");
            let sum = sums
                .get(series)
                .unwrap_or_else(|| panic!("{family}{{{series}}}: missing _sum sample"));
            if *count == 0.0 {
                assert_eq!(*sum, 0.0, "{family}{{{series}}}: empty series with nonzero sum");
            }
        }
    }
    assert!(!order.is_empty(), "exposition declared no families at all");
    families
}

/// Value of the single unlabeled sample of `family`.
fn scalar(families: &BTreeMap<String, (String, Vec<Sample>)>, family: &str) -> f64 {
    let (_, samples) = families
        .get(family)
        .unwrap_or_else(|| panic!("missing family {family}"));
    assert_eq!(samples.len(), 1, "{family} should carry exactly one sample");
    samples[0].value
}

/// Value of the request counter for (method, endpoint, class), 0 if the
/// pair never fired (zero-count pairs are silent by design).
fn requests(
    families: &BTreeMap<String, (String, Vec<Sample>)>,
    method: &str,
    endpoint: &str,
    class: &str,
) -> f64 {
    families["migsched_http_requests_total"]
        .1
        .iter()
        .find(|s| {
            s.label("method") == Some(method)
                && s.label("endpoint") == Some(endpoint)
                && s.label("class") == Some(class)
        })
        .map(|s| s.value)
        .unwrap_or(0.0)
}

#[test]
fn metrics_exposition_is_well_formed_and_matches_stats() {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 2,
        scheduler: SchedulerKind::Mfi,
        workers: 2,
        shards: 1,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    let client = HttpClient::new(&addr);

    // Scripted sequence: two full-GPU accepts, one reject, one release,
    // one tick — every counter lands on a known value.
    let mut ids = Vec::new();
    for _ in 0..2 {
        let r = client
            .post_json("/v1/workloads", &Json::obj().with("profile", "7g.80gb"))
            .expect("submit");
        assert_eq!(r.status, 201, "{}", r.body);
        ids.push(r.json().unwrap().req_u64("id").unwrap());
    }
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
        .expect("submit");
    assert_eq!(r.status, 409, "fleet is full: {}", r.body);
    let r = client.delete(&format!("/v1/workloads/{}", ids[0])).expect("release");
    assert_eq!(r.status, 200);
    let r = client.post_json("/v1/tick", &Json::obj().with("slots", 1u64)).expect("tick");
    assert_eq!(r.status, 200);
    let stats = client.get("/v1/stats").unwrap().json().unwrap();

    let (status, headers, body) = raw_get(&addr, "/metrics");
    assert_eq!(status, 200);
    assert_eq!(
        headers.get("content-type").map(String::as_str),
        Some("text/plain; version=0.0.4; charset=utf-8")
    );
    // A response is counted only after its bytes hit the socket, so the
    // keep-alive client's last response may still be in flight at render
    // time: any scrape sees requests >= responses, and equality holds
    // after quiescence — poll for it.
    let mut families = lint_exposition(&body);
    let total = |fs: &BTreeMap<String, (String, Vec<Sample>)>| -> (f64, f64) {
        let requests: f64 =
            fs["migsched_http_requests_total"].1.iter().map(|s| s.value).sum();
        (requests, scalar(fs, "migsched_http_responses_total"))
    };
    for attempt in 0.. {
        let (requests, responses) = total(&families);
        assert!(requests >= responses, "a scrape may never see responses ahead");
        if requests == responses {
            break;
        }
        assert!(attempt < 100, "requests never converged to responses");
        std::thread::sleep(std::time::Duration::from_millis(10));
        families = lint_exposition(&raw_get(&addr, "/metrics").2);
    }

    // Cluster counters match /v1/stats sample for sample.
    assert_eq!(scalar(&families, "migsched_submits_total"), 3.0);
    assert_eq!(
        scalar(&families, "migsched_submits_total"),
        stats.req_u64("arrived_total").unwrap() as f64
    );
    assert_eq!(
        scalar(&families, "migsched_accepted_total"),
        stats.req_u64("accepted_total").unwrap() as f64
    );
    assert_eq!(
        scalar(&families, "migsched_released_total"),
        stats.req_u64("released_total").unwrap() as f64
    );
    assert_eq!(
        scalar(&families, "migsched_expired_total"),
        stats.req_u64("expired_total").unwrap() as f64
    );
    assert_eq!(
        scalar(&families, "migsched_allocated_workloads"),
        stats.req_u64("allocated_workloads").unwrap() as f64
    );
    assert_eq!(scalar(&families, "migsched_clock_slot"), 1.0);
    assert_eq!(scalar(&families, "migsched_shards"), 1.0);
    assert_eq!(scalar(&families, "migsched_num_gpus"), 2.0);
    assert!(scalar(&families, "migsched_uptime_seconds") >= 0.0);

    // HTTP plane: the scripted requests landed on the right routes; the
    // in-flight /metrics scrape itself is recorded only after its
    // response renders, so it appears in neither side.
    assert_eq!(requests(&families, "POST", "/v1/workloads", "2xx"), 2.0);
    assert_eq!(requests(&families, "POST", "/v1/workloads", "4xx"), 1.0);
    assert_eq!(requests(&families, "DELETE", "/v1/workloads/{id}", "2xx"), 1.0);
    assert_eq!(requests(&families, "POST", "/v1/tick", "2xx"), 1.0);
    assert_eq!(requests(&families, "GET", "/v1/stats", "2xx"), 1.0);
    let total_requests: f64 =
        families["migsched_http_requests_total"].1.iter().map(|s| s.value).sum();
    assert_eq!(
        total_requests,
        scalar(&families, "migsched_http_responses_total"),
        "quiescent scrape: every dispatched request was answered"
    );
    assert!(scalar(&families, "migsched_http_connections_total") >= 2.0);

    // Scheduler plane: 3 decisions (2 accepts + 1 reject), ΔF recorded
    // only for the 2 commits.
    let count_of = |family: &str| -> f64 {
        families[family]
            .1
            .iter()
            .filter(|s| s.name.ends_with("_count"))
            .map(|s| s.value)
            .sum()
    };
    assert_eq!(count_of("migsched_sched_decision_seconds"), 3.0);
    assert_eq!(count_of("migsched_sched_delta_f_per_commit"), 2.0);
    // Each 7g.80gb commit fills a blank GPU: ΔF is identical for both, so
    // the per-shard sum is even and non-negative.
    let delta_sum: f64 = families["migsched_sched_delta_f_per_commit"]
        .1
        .iter()
        .filter(|s| s.name.ends_with("_sum"))
        .map(|s| s.value)
        .sum();
    assert_eq!(delta_sum % 2.0, 0.0);

    // A second scrape still lints and sees the earlier ones counted.
    let (_, _, body2) = raw_get(&addr, "/metrics");
    let families2 = lint_exposition(&body2);
    assert!(requests(&families2, "GET", "/metrics", "2xx") >= 1.0);

    handle.shutdown();
}

#[test]
fn healthz_and_version_over_the_socket() {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 4,
        workers: 1,
        shards: 2,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let client = HttpClient::new(&handle.addr().to_string());

    let r = client.get("/v1/healthz").expect("healthz");
    assert_eq!(r.status, 200);
    let j = r.json().unwrap();
    assert_eq!(j.req_str("status").unwrap(), "ok");
    assert!(j.get("uptime_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
    assert_eq!(j.req_u64("shards").unwrap(), 2);
    assert_eq!(j.req_u64("num_gpus").unwrap(), 4);

    let r = client.get("/v1/version").expect("version");
    assert_eq!(r.status, 200);
    let j = r.json().unwrap();
    assert_eq!(j.req_str("name").unwrap(), "migsched");
    assert_eq!(j.req_str("version").unwrap(), env!("CARGO_PKG_VERSION"));
    assert!(j.get("features").unwrap().as_arr().is_some());

    handle.shutdown();
}
