//! Live-socket integration tests: daemon + HTTP client over an ephemeral
//! port, covering the full request path (accept → parse → schedule →
//! respond) including concurrent submissions.

use std::io::{Read, Write};

use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;

fn start_daemon(num_gpus: usize) -> (migsched::server::ServerHandle, HttpClient) {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus,
        workers: 4,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind ephemeral port");
    let client = HttpClient::new(&handle.addr().to_string());
    (handle, client)
}

/// Write raw bytes to the daemon, half-close, and return whatever it
/// sends back — for protocol-level tests below the `HttpClient`
/// abstraction. The write side is shut down so the server sees EOF on
/// unterminated requests (and has consumed every byte before it closes,
/// keeping the response safe from a reset-with-unread-data).
fn raw_request(addr: &str, bytes: &[u8]) -> String {
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    stream.write_all(bytes).expect("write request");
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).expect("half-close");
    let mut out = Vec::new();
    let _ = stream.read_to_end(&mut out);
    String::from_utf8_lossy(&out).into_owned()
}

/// 8 KiB — mirror of `migsched::server::http::MAX_LINE`.
const MAX_LINE: usize = migsched::server::http::MAX_LINE;

#[test]
fn health_and_stats() {
    let (handle, client) = start_daemon(4);
    let r = client.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.body, "ok\n");

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("num_gpus").unwrap(), 4);
    assert_eq!(stats.req_u64("capacity_slices").unwrap(), 32);
    assert_eq!(stats.req_str("scheduler").unwrap(), "MFI");
    handle.shutdown();
}

#[test]
fn submit_and_release_over_the_wire() {
    let (handle, client) = start_daemon(2);
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "3g.40gb").with("tenant", 9u64))
        .unwrap();
    assert_eq!(r.status, 201, "{}", r.body);
    let j = r.json().unwrap();
    let id = j.req_u64("id").unwrap();
    assert_eq!(j.req_str("profile").unwrap(), "3g.40gb");

    let lookup = client.get(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(lookup.status, 200);
    assert_eq!(lookup.json().unwrap().req_u64("tenant").unwrap(), 9);

    let del = client.delete(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(del.status, 200);
    let lookup2 = client.get(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(lookup2.status, 404);
    handle.shutdown();
}

#[test]
fn rejection_when_fragmented_or_full() {
    let (handle, client) = start_daemon(1);
    // Fill the single GPU.
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "7g.80gb"))
        .unwrap();
    assert_eq!(r.status, 201);
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
        .unwrap();
    assert_eq!(r.status, 409);
    assert_eq!(r.json().unwrap().get("rejected").unwrap().as_bool(), Some(true));
    handle.shutdown();
}

#[test]
fn lease_expiry_via_tick_endpoint() {
    let (handle, client) = start_daemon(2);
    let r = client
        .post_json(
            "/v1/workloads",
            &Json::obj().with("profile", "2g.20gb").with("duration_slots", 3u64),
        )
        .unwrap();
    assert_eq!(r.status, 201);
    let tick = client.post_json("/v1/tick", &Json::obj().with("slots", 3u64)).unwrap();
    let j = tick.json().unwrap();
    assert_eq!(j.req_u64("clock_slot").unwrap(), 3);
    assert_eq!(j.get("released").unwrap().as_arr().unwrap().len(), 1);

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("allocated_workloads").unwrap(), 0);
    assert_eq!(stats.req_u64("expired_total").unwrap(), 1);
    handle.shutdown();
}

#[test]
fn concurrent_submissions_stay_consistent() {
    let (handle, client) = start_daemon(8);
    let addr = handle.addr().to_string();
    let threads: Vec<_> = (0..8)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client = HttpClient::new(&addr);
                let mut accepted = 0u64;
                for _ in 0..8 {
                    let r = client
                        .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
                        .unwrap();
                    if r.status == 201 {
                        accepted += 1;
                    }
                }
                accepted
            })
        })
        .collect();
    let total: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    // 8 GPUs × 7 1g-anchors = 56 feasible slots; 64 submissions.
    assert_eq!(total, 56, "exactly the feasible capacity must be accepted");

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("accepted_total").unwrap(), 56);
    assert_eq!(stats.req_u64("arrived_total").unwrap(), 64);
    // Occupancy diagrams line up with 7 slices used per GPU.
    let cluster = client.get("/v1/cluster").unwrap().json().unwrap();
    let diagrams = cluster.get("diagrams").unwrap().as_arr().unwrap();
    for d in diagrams {
        assert_eq!(d.as_str().unwrap().matches('#').count(), 7);
    }
    handle.shutdown();
}

#[test]
fn malformed_requests_get_4xx() {
    let (handle, client) = start_daemon(1);
    let r = client.post_json("/v1/workloads", &Json::obj()).unwrap();
    assert_eq!(r.status, 400);
    let r = client.get("/v1/definitely/not/a/route").unwrap();
    assert_eq!(r.status, 404);
    let r = client.get("/v1/workloads/not-a-number").unwrap();
    assert_eq!(r.status, 400);
    handle.shutdown();
}

#[test]
fn hardware_endpoint_reports_table_i() {
    let (handle, client) = start_daemon(1);
    let hw = client.get("/v1/hardware").unwrap().json().unwrap();
    assert_eq!(hw.req_str("model").unwrap(), "A100-80GB");
    let profiles = hw.get("profiles").unwrap().as_arr().unwrap();
    assert_eq!(profiles.len(), 6);
    let p7 = &profiles[0];
    assert_eq!(p7.req_str("name").unwrap(), "7g.80gb");
    assert_eq!(p7.req_u64("slices").unwrap(), 8);
    handle.shutdown();
}

#[test]
fn oversized_request_line_is_rejected_with_414() {
    // Regression: the request line used to be read without any bound, so
    // one endless line could allocate without limit — and this capped
    // request (no newline, one byte past the limit) was buffered whole
    // and answered 404 instead of 414 URI Too Long.
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    // "GET /aaaa…" of exactly MAX_LINE + 1 bytes, never newline-terminated.
    let request = format!("GET /{}", "a".repeat(MAX_LINE + 1 - 5));
    let reply = raw_request(&addr, request.as_bytes());
    assert!(
        reply.starts_with("HTTP/1.1 414 URI Too Long"),
        "want 414, got: {}",
        &reply[..reply.len().min(120)]
    );
    handle.shutdown();
}

#[test]
fn oversized_header_line_is_rejected_with_413() {
    // Pre-fix the whole junk header was buffered and answered 200.
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    let head = "GET /healthz HTTP/1.1\r\n";
    // One header line of exactly MAX_LINE + 1 bytes, never terminated.
    let junk = format!("x-junk: {}", "b".repeat(MAX_LINE + 1 - 8));
    let reply = raw_request(&addr, format!("{head}{junk}").as_bytes());
    assert!(
        reply.starts_with("HTTP/1.1 413"),
        "want 413, got: {}",
        &reply[..reply.len().min(120)]
    );
    // Lines within the cap still parse fine.
    let ok = raw_request(
        &addr,
        format!("GET /healthz HTTP/1.1\r\nx-pad: {}\r\n\r\n", "c".repeat(1024)).as_bytes(),
    );
    assert!(ok.starts_with("HTTP/1.1 200"), "{}", &ok[..ok.len().min(120)]);
    handle.shutdown();
}

#[test]
fn header_line_flood_is_rejected_with_400() {
    // Regression: the 100-header cap used to count parsed entries, so a
    // stream of colon-less (or duplicate-name) lines under the length cap
    // looped forever and pinned a worker. Now every header LINE counts —
    // the 101st junk line below trips the cap (pre-fix: parsed 0 headers
    // and kept reading; with a terminated request it answered 200).
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    // Exactly 101 junk lines and no terminating blank line: the server
    // rejects on the 101st with every sent byte consumed.
    let flood = format!("GET /healthz HTTP/1.1\r\n{}", "junk-no-colon\r\n".repeat(101));
    let reply = raw_request(&addr, flood.as_bytes());
    assert!(
        reply.starts_with("HTTP/1.1 400"),
        "want 400, got: {}",
        &reply[..reply.len().min(120)]
    );
    handle.shutdown();
}

#[test]
fn keep_alive_pipelines_requests_on_one_connection() {
    // Three HTTP/1.1 requests written back-to-back on ONE connection (no
    // Connection header → keep-alive by default): the daemon must answer
    // all three in order without dropping buffered pipeline bytes.
    let (handle, _client) = start_daemon(2);
    let addr = handle.addr().to_string();
    let pipeline = "GET /healthz HTTP/1.1\r\n\r\n".repeat(3);
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert_eq!(
        reply.matches("HTTP/1.1 200 OK").count(),
        3,
        "want 3 responses on one connection, got: {reply}"
    );
    assert_eq!(reply.matches("ok\n").count(), 3);
    assert!(reply.contains("Connection: keep-alive"));
    handle.shutdown();
}

#[test]
fn keep_alive_serves_stateful_requests_in_order() {
    // Submit + stats pipelined on one connection: the second response
    // must observe the first request's effect (strict ordering).
    let (handle, _client) = start_daemon(2);
    let addr = handle.addr().to_string();
    let body = "{\"profile\":\"3g.40gb\",\"tenant\":1}";
    let pipeline = format!(
        "POST /v1/workloads HTTP/1.1\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}GET /v1/stats HTTP/1.1\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert!(reply.contains("HTTP/1.1 201"), "{reply}");
    // The stats response (second on the wire) sees the allocation.
    let stats_at = reply.find("\"allocated_workloads\"").expect("stats response present");
    assert!(
        reply[stats_at..].starts_with("\"allocated_workloads\":1"),
        "stats must observe the pipelined submit: {reply}"
    );
    handle.shutdown();
}

#[test]
fn connection_close_is_honored_mid_pipeline() {
    // The first request opts out of keep-alive; a second pipelined
    // request must NOT be served.
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    let pipeline = "GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n\
                    GET /healthz HTTP/1.1\r\n\r\n";
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert_eq!(reply.matches("HTTP/1.1 200 OK").count(), 1, "{reply}");
    assert!(reply.contains("Connection: close"));
    handle.shutdown();
}

#[test]
fn keep_alive_request_cap_closes_the_connection() {
    use migsched::server::daemon::MAX_REQUESTS_PER_CONN;
    // Two more requests than the cap: exactly cap-many are answered, the
    // last answered one advertises Connection: close.
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    let pipeline = "GET /healthz HTTP/1.1\r\n\r\n".repeat(MAX_REQUESTS_PER_CONN + 2);
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert_eq!(
        reply.matches("HTTP/1.1 200 OK").count(),
        MAX_REQUESTS_PER_CONN,
        "cap must bound one connection: {}",
        reply.len()
    );
    let last_close = reply.rfind("Connection: close").expect("final response closes");
    assert!(reply[last_close..].contains("ok\n"));
    assert_eq!(reply.matches("Connection: close").count(), 1);
    handle.shutdown();
}

#[test]
fn http_1_0_without_opt_in_closes_after_one_response() {
    let (handle, _client) = start_daemon(1);
    let addr = handle.addr().to_string();
    let pipeline = "GET /healthz HTTP/1.0\r\n\r\nGET /healthz HTTP/1.0\r\n\r\n";
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert_eq!(reply.matches("HTTP/1.1 200 OK").count(), 1, "{reply}");
    handle.shutdown();
}

#[test]
fn shutdown_completes_when_bound_to_unspecified_address() {
    // Regression: shutdown wakes the accept loop with a dummy connect to
    // the bind address — dialing 0.0.0.0 hangs forever on some platforms,
    // so the wake-up must go through loopback.
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 1,
        workers: 1,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("0.0.0.0:0").expect("bind 0.0.0.0");
    let port = handle.addr().port();
    let client = HttpClient::new(&format!("127.0.0.1:{port}"));
    assert_eq!(client.get("/healthz").unwrap().status, 200);

    let shutdown = std::thread::spawn(move || handle.shutdown());
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while !shutdown.is_finished() {
        assert!(
            std::time::Instant::now() < deadline,
            "shutdown hung while bound to 0.0.0.0"
        );
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    shutdown.join().unwrap();
}

#[test]
fn sharded_daemon_serves_disjoint_subclusters() {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 8,
        workers: 4,
        shards: 4,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let client = HttpClient::new(&handle.addr().to_string());
    // 8 GPUs over 4 shards → 2 GPUs per shard; the reported (global) gpu
    // id reveals the shard. A tenant must stay on one shard, and the id
    // must encode that shard (id mod 4).
    let mut shard_of_tenant = std::collections::HashMap::new();
    let mut ids = Vec::new();
    for tenant in 0..7u64 {
        for _ in 0..2 {
            let r = client
                .post_json(
                    "/v1/workloads",
                    &Json::obj().with("profile", "1g.10gb").with("tenant", tenant),
                )
                .unwrap();
            assert_eq!(r.status, 201, "{}", r.body);
            let j = r.json().unwrap();
            let gpu = j.req_u64("gpu").unwrap() as usize;
            let id = j.req_u64("id").unwrap();
            let shard = gpu / 2;
            assert_eq!(id as usize % 4, shard, "ids encode their shard");
            if let Some(prev) = shard_of_tenant.insert(tenant, shard) {
                assert_eq!(prev, shard, "tenant {tenant} hopped shards");
            }
            ids.push(id);
        }
    }
    // Fleet-wide views merge all shards in a stable order.
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("num_gpus").unwrap(), 8);
    assert_eq!(stats.req_u64("shards").unwrap(), 4);
    assert_eq!(stats.req_u64("accepted_total").unwrap(), 14);
    let snap = client.get("/v1/cluster").unwrap().json().unwrap();
    assert_eq!(snap.get("gpu_masks").unwrap().as_arr().unwrap().len(), 8);
    let allocs = snap.get("allocations").unwrap().as_arr().unwrap();
    assert_eq!(allocs.len(), 14);
    // Stable merge order: allocations sorted by workload id.
    let listed: Vec<u64> = allocs.iter().map(|a| a.req_u64("workload").unwrap()).collect();
    let mut sorted = listed.clone();
    sorted.sort_unstable();
    assert_eq!(listed, sorted, "merged allocations must be id-sorted");
    // Cross-shard lookup + release by id.
    for id in ids {
        assert_eq!(client.get(&format!("/v1/workloads/{id}")).unwrap().status, 200);
        assert_eq!(client.delete(&format!("/v1/workloads/{id}")).unwrap().status, 200);
    }
    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("allocated_workloads").unwrap(), 0);
    handle.shutdown();
}

#[test]
fn defrag_endpoint_repairs_fragmentation_and_rehosts_rejected_profile() {
    // Build a fragmented fleet through the serving path: fill all 1g
    // anchors on 3 GPUs, then terminate everything except the workload at
    // index 4 on each GPU. Every GPU then hosts one stranded 1g slice, so
    // a 7g.80gb is rejected — until the defrag endpoint consolidates.
    let (handle, client) = start_daemon(3);
    let mut keep = Vec::new();
    let mut drop = Vec::new();
    for _ in 0..21 {
        let r = client
            .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
            .unwrap();
        assert_eq!(r.status, 201, "{}", r.body);
        let j = r.json().unwrap();
        if j.req_u64("index").unwrap() == 4 {
            keep.push(j.req_u64("id").unwrap());
        } else {
            drop.push(j.req_u64("id").unwrap());
        }
    }
    assert_eq!(keep.len(), 3, "one index-4 anchor per GPU");
    for id in drop {
        assert_eq!(client.delete(&format!("/v1/workloads/{id}")).unwrap().status, 200);
    }
    // Fragmented: the full-GPU profile has nowhere to go.
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "7g.80gb"))
        .unwrap();
    assert_eq!(r.status, 409, "fragmented fleet must reject 7g.80gb");

    // Maintenance: plan + apply migrations, report ΔF < 0.
    let r = client.post_json("/v1/maintenance/defrag", &Json::obj()).unwrap();
    assert_eq!(r.status, 200, "{}", r.body);
    let j = r.json().unwrap();
    assert!(j.req_u64("migrations").unwrap() > 0, "{}", r.body);
    let delta = j.get("delta_f").unwrap().as_f64().unwrap();
    assert!(delta < 0.0, "defrag must lower total F, got {delta}");

    // The previously rejected profile now fits.
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "7g.80gb"))
        .unwrap();
    assert_eq!(r.status, 201, "defragged fleet re-hosts 7g.80gb: {}", r.body);
    // The three survivors are still alive (migrated, not dropped).
    for id in keep {
        assert_eq!(client.get(&format!("/v1/workloads/{id}")).unwrap().status, 200);
    }
    handle.shutdown();
}

#[test]
fn batch_submit_over_the_wire_on_both_models() {
    use migsched::server::ServeModel;
    for model in [ServeModel::Reactor.effective(), ServeModel::Threadpool] {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 2,
            workers: 2,
            model,
            ..DaemonConfig::default()
        });
        let handle = daemon.serve("127.0.0.1:0").expect("bind");
        let client = HttpClient::new(&handle.addr().to_string());
        // Two full-GPU placements fill the fleet; the third item rejects.
        let batch = Json::obj().with(
            "requests",
            Json::Arr(vec![
                Json::obj().with("profile", "7g.80gb").with("tenant", 1u64),
                Json::obj().with("profile", "7g.80gb").with("tenant", 2u64),
                Json::obj().with("profile", "1g.10gb").with("tenant", 3u64),
            ]),
        );
        let r = client.post_json("/v1/submit/batch", &batch).unwrap();
        assert_eq!(r.status, 200, "[{}] {}", model.name(), r.body);
        let j = r.json().unwrap();
        assert_eq!(j.req_u64("accepted").unwrap(), 2, "[{}]", model.name());
        assert_eq!(j.req_u64("rejected").unwrap(), 1, "[{}]", model.name());
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        assert!(results[0].req_u64("id").is_ok(), "placed item has an id");
        assert_eq!(results[2].get("rejected").unwrap().as_bool(), Some(true));
        // The amortized path feeds the same counters as plain submits.
        let stats = client.get("/v1/stats").unwrap().json().unwrap();
        assert_eq!(stats.req_u64("arrived_total").unwrap(), 3, "[{}]", model.name());
        assert_eq!(stats.req_u64("accepted_total").unwrap(), 2, "[{}]", model.name());
        handle.shutdown();
    }
}

#[test]
fn version_reports_the_serving_configuration() {
    use migsched::server::ServeModel;
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 1,
        workers: 1,
        model: ServeModel::Threadpool,
        idle_timeout: std::time::Duration::from_millis(1234),
        max_requests_per_conn: 5,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let client = HttpClient::new(&handle.addr().to_string());
    let v = client.get("/v1/version").unwrap().json().unwrap();
    assert_eq!(v.req_str("serve_model").unwrap(), "threadpool");
    assert_eq!(v.req_u64("idle_timeout_ms").unwrap(), 1234);
    assert_eq!(v.req_u64("max_requests_per_conn").unwrap(), 5);
    handle.shutdown();
}

#[test]
fn configured_request_cap_bounds_a_connection() {
    // A cap of 2 must answer exactly 2 of 4 pipelined requests, closing
    // on the second — on both serve models.
    use migsched::server::ServeModel;
    for model in [ServeModel::Reactor.effective(), ServeModel::Threadpool] {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 1,
            workers: 1,
            model,
            max_requests_per_conn: 2,
            ..DaemonConfig::default()
        });
        let handle = daemon.serve("127.0.0.1:0").expect("bind");
        let addr = handle.addr().to_string();
        let pipeline = "GET /healthz HTTP/1.1\r\n\r\n".repeat(4);
        let reply = raw_request(&addr, pipeline.as_bytes());
        assert_eq!(
            reply.matches("HTTP/1.1 200 OK").count(),
            2,
            "[{}] configured cap must bound the connection: {reply}",
            model.name()
        );
        assert_eq!(reply.matches("Connection: close").count(), 1, "[{}]", model.name());
        handle.shutdown();
    }
}

#[test]
fn configured_idle_timeout_closes_idle_connections() {
    // After one kept-alive response the server must hang up on its own
    // once the (shortened) idle timeout elapses; the read below would
    // instead fail with a 10 s client-side timeout if it never did.
    use migsched::server::ServeModel;
    for model in [ServeModel::Reactor.effective(), ServeModel::Threadpool] {
        let daemon = Daemon::new(DaemonConfig {
            num_gpus: 1,
            workers: 1,
            model,
            idle_timeout: std::time::Duration::from_millis(250),
            ..DaemonConfig::default()
        });
        let handle = daemon.serve("127.0.0.1:0").expect("bind");
        let addr = handle.addr().to_string();
        let mut stream = std::net::TcpStream::connect(&addr).expect("connect");
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .unwrap();
        stream.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        stream.flush().unwrap();
        // Deliberately NO half-close: the connection stays open and idle.
        let t0 = std::time::Instant::now();
        let mut out = Vec::new();
        stream
            .read_to_end(&mut out)
            .expect("server closes the idle connection before the client timeout");
        let reply = String::from_utf8_lossy(&out);
        assert!(reply.starts_with("HTTP/1.1 200 OK"), "[{}] {reply}", model.name());
        assert!(reply.contains("Connection: keep-alive"), "[{}] {reply}", model.name());
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(9),
            "[{}] connection closed by idle timeout, not client timeout",
            model.name()
        );
        handle.shutdown();
    }
}

#[test]
fn threadpool_model_still_serves_pipelined_and_stateful_requests() {
    // The blocking fallback stays a first-class citizen: pipelining,
    // strict ordering and the submit/release cycle all work.
    use migsched::server::ServeModel;
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 2,
        workers: 2,
        model: ServeModel::Threadpool,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let addr = handle.addr().to_string();
    let pipeline = "GET /healthz HTTP/1.1\r\n\r\n".repeat(3);
    let reply = raw_request(&addr, pipeline.as_bytes());
    assert_eq!(reply.matches("HTTP/1.1 200 OK").count(), 3, "{reply}");

    let client = HttpClient::new(&addr);
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "3g.40gb").with("tenant", 4u64))
        .unwrap();
    assert_eq!(r.status, 201, "{}", r.body);
    let id = r.json().unwrap().req_u64("id").unwrap();
    assert_eq!(client.delete(&format!("/v1/workloads/{id}")).unwrap().status, 200);
    handle.shutdown();
}

#[test]
fn persistent_client_reuses_one_connection_and_recovers_from_caps() {
    use migsched::server::HttpConn;
    // More requests than the per-connection cap: HttpConn must ride the
    // keep-alive connection to the cap, then transparently reconnect.
    let daemon = Daemon::new(DaemonConfig {
        num_gpus: 1,
        workers: 1,
        max_requests_per_conn: 3,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind");
    let mut conn = HttpConn::connect(&handle.addr().to_string());
    for i in 0..10 {
        let r = conn.get("/healthz").unwrap();
        assert_eq!(r.status, 200, "request {i}");
        assert_eq!(r.body, "ok\n", "request {i}");
    }
    let stats = conn.get("/v1/stats").unwrap();
    assert_eq!(stats.status, 200);
    handle.shutdown();
}
