//! Live-socket integration tests: daemon + HTTP client over an ephemeral
//! port, covering the full request path (accept → parse → schedule →
//! respond) including concurrent submissions.

use migsched::server::{Daemon, DaemonConfig, HttpClient};
use migsched::util::json::Json;

fn start_daemon(num_gpus: usize) -> (migsched::server::ServerHandle, HttpClient) {
    let daemon = Daemon::new(DaemonConfig {
        num_gpus,
        workers: 4,
        ..DaemonConfig::default()
    });
    let handle = daemon.serve("127.0.0.1:0").expect("bind ephemeral port");
    let client = HttpClient::new(&handle.addr().to_string());
    (handle, client)
}

#[test]
fn health_and_stats() {
    let (handle, client) = start_daemon(4);
    let r = client.get("/healthz").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.body, "ok\n");

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("num_gpus").unwrap(), 4);
    assert_eq!(stats.req_u64("capacity_slices").unwrap(), 32);
    assert_eq!(stats.req_str("scheduler").unwrap(), "MFI");
    handle.shutdown();
}

#[test]
fn submit_and_release_over_the_wire() {
    let (handle, client) = start_daemon(2);
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "3g.40gb").with("tenant", 9u64))
        .unwrap();
    assert_eq!(r.status, 201, "{}", r.body);
    let j = r.json().unwrap();
    let id = j.req_u64("id").unwrap();
    assert_eq!(j.req_str("profile").unwrap(), "3g.40gb");

    let lookup = client.get(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(lookup.status, 200);
    assert_eq!(lookup.json().unwrap().req_u64("tenant").unwrap(), 9);

    let del = client.delete(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(del.status, 200);
    let lookup2 = client.get(&format!("/v1/workloads/{id}")).unwrap();
    assert_eq!(lookup2.status, 404);
    handle.shutdown();
}

#[test]
fn rejection_when_fragmented_or_full() {
    let (handle, client) = start_daemon(1);
    // Fill the single GPU.
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "7g.80gb"))
        .unwrap();
    assert_eq!(r.status, 201);
    let r = client
        .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
        .unwrap();
    assert_eq!(r.status, 409);
    assert_eq!(r.json().unwrap().get("rejected").unwrap().as_bool(), Some(true));
    handle.shutdown();
}

#[test]
fn lease_expiry_via_tick_endpoint() {
    let (handle, client) = start_daemon(2);
    let r = client
        .post_json(
            "/v1/workloads",
            &Json::obj().with("profile", "2g.20gb").with("duration_slots", 3u64),
        )
        .unwrap();
    assert_eq!(r.status, 201);
    let tick = client.post_json("/v1/tick", &Json::obj().with("slots", 3u64)).unwrap();
    let j = tick.json().unwrap();
    assert_eq!(j.req_u64("clock_slot").unwrap(), 3);
    assert_eq!(j.get("released").unwrap().as_arr().unwrap().len(), 1);

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("allocated_workloads").unwrap(), 0);
    assert_eq!(stats.req_u64("expired_total").unwrap(), 1);
    handle.shutdown();
}

#[test]
fn concurrent_submissions_stay_consistent() {
    let (handle, client) = start_daemon(8);
    let addr = handle.addr().to_string();
    let threads: Vec<_> = (0..8)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let client = HttpClient::new(&addr);
                let mut accepted = 0u64;
                for _ in 0..8 {
                    let r = client
                        .post_json("/v1/workloads", &Json::obj().with("profile", "1g.10gb"))
                        .unwrap();
                    if r.status == 201 {
                        accepted += 1;
                    }
                }
                accepted
            })
        })
        .collect();
    let total: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    // 8 GPUs × 7 1g-anchors = 56 feasible slots; 64 submissions.
    assert_eq!(total, 56, "exactly the feasible capacity must be accepted");

    let stats = client.get("/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.req_u64("accepted_total").unwrap(), 56);
    assert_eq!(stats.req_u64("arrived_total").unwrap(), 64);
    // Occupancy diagrams line up with 7 slices used per GPU.
    let cluster = client.get("/v1/cluster").unwrap().json().unwrap();
    let diagrams = cluster.get("diagrams").unwrap().as_arr().unwrap();
    for d in diagrams {
        assert_eq!(d.as_str().unwrap().matches('#').count(), 7);
    }
    handle.shutdown();
}

#[test]
fn malformed_requests_get_4xx() {
    let (handle, client) = start_daemon(1);
    let r = client.post_json("/v1/workloads", &Json::obj()).unwrap();
    assert_eq!(r.status, 400);
    let r = client.get("/v1/definitely/not/a/route").unwrap();
    assert_eq!(r.status, 404);
    let r = client.get("/v1/workloads/not-a-number").unwrap();
    assert_eq!(r.status, 400);
    handle.shutdown();
}

#[test]
fn hardware_endpoint_reports_table_i() {
    let (handle, client) = start_daemon(1);
    let hw = client.get("/v1/hardware").unwrap().json().unwrap();
    assert_eq!(hw.req_str("model").unwrap(), "A100-80GB");
    let profiles = hw.get("profiles").unwrap().as_arr().unwrap();
    assert_eq!(profiles.len(), 6);
    let p7 = &profiles[0];
    assert_eq!(p7.req_str("name").unwrap(), "7g.80gb");
    assert_eq!(p7.req_u64("slices").unwrap(), 8);
    handle.shutdown();
}
