//! Heterogeneous-fleet cross-layer properties.
//!
//! * On ANY interleaving of schedule / commit / release operations over a
//!   randomly interleaved mixed fleet, the indexed scheduler (`MFI-IDX`)
//!   must produce bit-identical placements to the flat per-class rescan
//!   (`MFI` / `evaluate_fleet`) — extending the PR 2 equivalence suite
//!   (`tests/incremental.rs`) from uniform clusters to arbitrary class
//!   layouts.
//! * `FleetSpec::partition` conserves every class's GPU count across any
//!   shard count.
//! * A single-class fleet is a strict special case: snapshots serialize
//!   byte-identically to the legacy constructor's.

use migsched::cluster::{snapshot, Cluster};
use migsched::frag::{evaluate_fleet, FleetTables};
use migsched::mig::{FleetSpec, HardwareModel, Placement, Profile, ALL_PROFILES};
use migsched::sched::{Mfi, MfiExpected, MfiIndexed, Scheduler};
use migsched::util::check::forall_shrink_vec;
use migsched::workload::{EstimatorConfig, WorkloadId};

/// The class vocabulary random layouts draw from: three models with two
/// distinct per-slice memories, so nearest-fit and ΔF pricing genuinely
/// differ across classes.
fn models() -> Vec<HardwareModel> {
    vec![
        HardwareModel::a100_80gb(),
        HardwareModel::h100_80gb(),
        HardwareModel::a100_40gb(),
    ]
}

/// Build a 5-GPU cluster whose per-GPU class is drawn from `seed` — an
/// arbitrary interleaving, not contiguous class runs.
fn cluster_from_seed(seed: u64) -> Cluster {
    let layout: Vec<u8> = (0..5).map(|g| ((seed >> (2 * g)) % 3) as u8).collect();
    Cluster::from_class_layout(models(), layout)
}

/// Replay an op-encoded episode against both schedulers on one shared
/// mixed cluster; every proposal must match. Encoding (shrinkable
/// `Vec<u64>`): ops[0] seeds the class layout; thereafter `op % 4 < 3` →
/// arrival of profile `(op / 4) % 6`, `op % 4 == 3` → release of the
/// `(op / 4) % live`-th oldest live workload.
fn drive_and_compare(ops: &[u64], hooks: bool) -> Result<(), String> {
    let (seed, ops) = match ops.split_first() {
        Some(x) => x,
        None => return Ok(()),
    };
    let hw = HardwareModel::a100_80gb();
    let mut flat = Mfi::for_hardware(&hw);
    let mut indexed = MfiIndexed::for_hardware(&hw);
    let mut cluster = cluster_from_seed(*seed);
    let mut live: Vec<WorkloadId> = Vec::new();
    let mut next_id = 0u64;
    for (step, &op) in ops.iter().enumerate() {
        if op % 4 < 3 || live.is_empty() {
            let profile = Profile::from_index(((op / 4) % 6) as usize).unwrap();
            let a = flat.schedule(&cluster, profile);
            let b = indexed.schedule(&cluster, profile);
            if a != b {
                return Err(format!(
                    "step {step}: {profile} → MFI {a:?} vs MFI-IDX {b:?} \
                     (hooks={hooks}, layout={:?})",
                    cluster.class_ids()
                ));
            }
            if let Some(placement) = a {
                let id = WorkloadId(next_id);
                next_id += 1;
                cluster.allocate(id, placement).map_err(|e| format!("step {step}: {e}"))?;
                if hooks {
                    indexed.on_commit(&cluster, placement);
                }
                live.push(id);
            }
        } else {
            let victim = live.remove(((op / 4) as usize) % live.len());
            let freed = cluster.release(victim).map_err(|e| format!("step {step}: {e}"))?;
            if hooks {
                indexed.on_release(&cluster, freed);
            }
        }
    }
    // Terminal state: every profile's argmin must still agree with the
    // from-scratch per-class fleet scan.
    let tables = FleetTables::for_cluster(&cluster);
    for p in ALL_PROFILES {
        let want = evaluate_fleet(&tables, &cluster, p);
        let got = indexed.schedule(&cluster, p);
        if got != want {
            return Err(format!(
                "terminal {p}: {got:?} vs {want:?} (hooks={hooks}, layout={:?})",
                cluster.class_ids()
            ));
        }
    }
    Ok(())
}

/// Same episode encoding as [`drive_and_compare`], but pitting the
/// distribution-aware MFI-EXP against flat MFI. With an *empty* estimator
/// (no mass observed) or a *uniform* seed (equal mass on every profile),
/// expected-fragmentation scoring must degenerate to the agnostic
/// objective bit-for-bit on any class layout — empty falls back to the
/// agnostic scorer outright, and a uniform mix scales every entry of
/// every class's table by the same constant, which preserves the strict
/// `(ΔF, gpu, anchor)` order including ties.
fn drive_and_compare_expected(ops: &[u64]) -> Result<(), String> {
    let (seed, ops) = match ops.split_first() {
        Some(x) => x,
        None => return Ok(()),
    };
    let hw = HardwareModel::a100_80gb();
    let mut flat = Mfi::for_hardware(&hw);
    let mut empty = MfiExpected::for_hardware(&hw);
    let uniform_cfg = EstimatorConfig { decay_slots: 0, seed_counts: Some([1; 6]) };
    let mut uniform = MfiExpected::with_config(&hw, &uniform_cfg);
    let mut cluster = cluster_from_seed(*seed);
    let mut live: Vec<WorkloadId> = Vec::new();
    let mut next_id = 0u64;
    for (step, &op) in ops.iter().enumerate() {
        if op % 4 < 3 || live.is_empty() {
            let profile = Profile::from_index(((op / 4) % 6) as usize).unwrap();
            let want = flat.schedule(&cluster, profile);
            // The estimators are deliberately never fed `on_commit`: the
            // property is about the empty/uniform mix, not the online one.
            let got_empty = empty.schedule(&cluster, profile);
            let got_uniform = uniform.schedule(&cluster, profile);
            if got_empty != want || got_uniform != want {
                return Err(format!(
                    "step {step}: {profile} → MFI {want:?} vs MFI-EXP(empty) \
                     {got_empty:?} vs MFI-EXP(uniform) {got_uniform:?} (layout={:?})",
                    cluster.class_ids()
                ));
            }
            if let Some(placement) = want {
                let id = WorkloadId(next_id);
                next_id += 1;
                cluster.allocate(id, placement).map_err(|e| format!("step {step}: {e}"))?;
                live.push(id);
            }
        } else {
            let victim = live.remove(((op / 4) as usize) % live.len());
            cluster.release(victim).map_err(|e| format!("step {step}: {e}"))?;
        }
    }
    Ok(())
}

#[test]
fn prop_fleet_mfi_exp_empty_or_uniform_equals_flat() {
    forall_shrink_vec(
        "fleet-mfi-exp-degenerate-equivalence",
        |rng| (0..1 + rng.index(120)).map(|_| rng.next_u64()).collect(),
        drive_and_compare_expected,
    );
}

#[test]
fn prop_fleet_indexed_equals_flat_with_hooks() {
    forall_shrink_vec(
        "fleet-mfi-idx-equivalence-hooked",
        |rng| (0..1 + rng.index(120)).map(|_| rng.next_u64()).collect(),
        |ops| drive_and_compare(ops, true),
    );
}

#[test]
fn prop_fleet_indexed_equals_flat_with_hooks_dropped() {
    // Same property with the hooks never called: the indexed scheduler
    // must fall back to change-log catch-up and stay identical.
    forall_shrink_vec(
        "fleet-mfi-idx-equivalence-hookless",
        |rng| (0..1 + rng.index(120)).map(|_| rng.next_u64()).collect(),
        |ops| drive_and_compare(ops, false),
    );
}

#[test]
fn prop_partition_conserves_every_class() {
    // Encoding: ops[0] → shard count (1..=4); each further op → one class
    // with 1..=5 GPUs (up to 3 classes used round-robin over the model
    // vocabulary, duplicates merged by construction order).
    forall_shrink_vec(
        "fleet-partition-conservation",
        |rng| (0..2 + rng.index(3)).map(|_| rng.next_u64()).collect(),
        |ops| {
            let (first, rest) = match ops.split_first() {
                Some(x) => x,
                None => return Ok(()),
            };
            if rest.is_empty() {
                return Ok(());
            }
            let shards = 1 + (*first % 4) as usize;
            let vocabulary = models();
            let classes: Vec<(HardwareModel, usize)> = rest
                .iter()
                .take(3)
                .enumerate()
                .map(|(i, op)| (vocabulary[i % 3].clone(), 1 + (op % 5) as usize))
                .collect();
            let fleet = FleetSpec::new(classes).map_err(|e| e.to_string())?;
            let parts = fleet.partition(shards);
            if parts.len() != shards {
                return Err(format!("{} rows for {shards} shards", parts.len()));
            }
            for (class, &want) in fleet.counts().iter().enumerate() {
                let got: usize = parts.iter().map(|row| row[class]).sum();
                if got != want {
                    return Err(format!(
                        "class {class}: {got} GPUs across shards, fleet has {want} \
                         (spec={}, shards={shards})",
                        fleet.spec_string()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn uniform_fleet_snapshot_bytes_match_legacy() {
    let fleet = FleetSpec::parse("a100:3").unwrap();
    let mut from_fleet = Cluster::from_fleet(&fleet);
    let mut legacy = Cluster::new(HardwareModel::a100_80gb(), 3);
    for (id, (gpu, profile, index)) in [
        (0, Profile::P3g40gb, 0),
        (1, Profile::P1g10gb, 5),
        (2, Profile::P2g20gb, 2),
    ]
    .into_iter()
    .enumerate()
    {
        let placement = Placement { gpu, profile, index };
        from_fleet.allocate(WorkloadId(id as u64), placement).unwrap();
        legacy.allocate(WorkloadId(id as u64), placement).unwrap();
    }
    let a = snapshot::to_json(&from_fleet).to_string_compact();
    let b = snapshot::to_json(&legacy).to_string_compact();
    assert_eq!(a, b, "single-class fleet must serialize byte-identically");
    assert!(a.contains("\"hardware\""), "uniform snapshots stay on the v1 format");
    assert!(!a.contains("gpu_classes"));
}
