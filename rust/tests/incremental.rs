//! Incremental-engine conformance: on ANY interleaving of schedule /
//! commit / release operations, the indexed scheduler (`MFI-IDX`) must
//! produce bit-identical placements to the flat-rescan reference (`MFI` /
//! `evaluate_cluster`) — with the driver calling the `on_commit` /
//! `on_release` hooks, with the hooks dropped entirely (change-log
//! catch-up), and across change-log discontinuities (index rebuild).

use migsched::cluster::{Cluster, CHANGE_LOG_CAPACITY};
use migsched::frag::evaluate_cluster;
use migsched::mig::{HardwareModel, Placement, Profile, ALL_PROFILES};
use migsched::sched::{Mfi, MfiIndexed, Scheduler, SchedulerKind};
use migsched::util::check::forall_shrink_vec;
use migsched::workload::WorkloadId;

/// Replay an op-encoded episode against both schedulers on one shared
/// cluster; every proposal must match. Encoding (shrinkable `Vec<u64>`):
/// `op % 4 < 3` → arrival of profile `(op / 4) % 6`; `op % 4 == 3` →
/// release of the `(op / 4) % live`-th oldest live workload.
fn drive_and_compare(ops: &[u64], gpus: usize, hooks: bool) -> Result<(), String> {
    let hw = HardwareModel::a100_80gb();
    let mut flat = Mfi::for_hardware(&hw);
    let mut indexed = MfiIndexed::for_hardware(&hw);
    let mut cluster = Cluster::new(hw, gpus);
    let mut live: Vec<WorkloadId> = Vec::new();
    let mut next_id = 0u64;
    for (step, &op) in ops.iter().enumerate() {
        if op % 4 < 3 || live.is_empty() {
            let profile = Profile::from_index(((op / 4) % 6) as usize).unwrap();
            let a = flat.schedule(&cluster, profile);
            let b = indexed.schedule(&cluster, profile);
            if a != b {
                return Err(format!(
                    "step {step}: {profile} → MFI {a:?} vs MFI-IDX {b:?} (hooks={hooks})"
                ));
            }
            if let Some(placement) = a {
                let id = WorkloadId(next_id);
                next_id += 1;
                cluster.allocate(id, placement).map_err(|e| format!("step {step}: {e}"))?;
                if hooks {
                    indexed.on_commit(&cluster, placement);
                }
                live.push(id);
            }
        } else {
            let victim = live.remove(((op / 4) as usize) % live.len());
            let freed = cluster.release(victim).map_err(|e| format!("step {step}: {e}"))?;
            if hooks {
                indexed.on_release(&cluster, freed);
            }
        }
    }
    // Terminal state: every profile's argmin must still agree.
    for p in ALL_PROFILES {
        let want = evaluate_cluster(flat.score_table(), cluster.gpus(), p);
        let got = indexed.schedule(&cluster, p);
        if got != want {
            return Err(format!("terminal {p}: {got:?} vs {want:?} (hooks={hooks})"));
        }
    }
    Ok(())
}

#[test]
fn prop_indexed_equals_flat_with_hooks() {
    forall_shrink_vec(
        "mfi-idx-equivalence-hooked",
        |rng| (0..rng.index(120)).map(|_| rng.next_u64()).collect(),
        |ops| drive_and_compare(ops, 4, true),
    );
}

#[test]
fn prop_indexed_equals_flat_with_hooks_dropped() {
    // Same property with the hooks never called: the scheduler must fall
    // back to change-log catch-up inside `schedule` and stay identical.
    forall_shrink_vec(
        "mfi-idx-equivalence-hookless",
        |rng| (0..rng.index(120)).map(|_| rng.next_u64()).collect(),
        |ops| drive_and_compare(ops, 3, false),
    );
}

#[test]
fn kind_built_indexed_matches_reference_through_sim_driver() {
    // `SchedulerKind::MfiIdx` (the flag-selectable construction) through
    // the real simulation driver: identical aggregate results to MFI.
    use migsched::sim::{Distribution, SimConfig, SimEngine};
    let cfg = SimConfig::small(Distribution::Bimodal, 0xD1CE);
    let engine = SimEngine::new(cfg.clone());
    let mut flat = SchedulerKind::Mfi.build(&cfg.hardware);
    let mut indexed = SchedulerKind::MfiIdx.build(&cfg.hardware);
    let a = engine.run(&mut *flat);
    let b = engine.run(&mut *indexed);
    assert_eq!(a.accepted, b.accepted);
    assert_eq!(a.time_avg_frag, b.time_avg_frag);
    assert_eq!(a.final_metrics, b.final_metrics);
}

#[test]
fn stale_index_resyncs_instead_of_diverging() {
    let hw = HardwareModel::a100_80gb();
    let mut indexed = MfiIndexed::for_hardware(&hw);
    let mut cluster = Cluster::new(hw.clone(), 4);

    // Build once.
    let first = indexed.schedule(&cluster, Profile::P2g20gb).unwrap();
    cluster.allocate(WorkloadId(0), first).unwrap();
    indexed.on_commit(&cluster, first);
    assert_eq!(indexed.rebuilds(), 1);

    // (a) Hooks dropped for a burst of mutations: the next schedule call
    // detects the generation gap and replays the change log — no rebuild.
    let mut id = 1u64;
    for i in 0..10u64 {
        let gpu = (i % 4) as usize;
        let anchor = (i % 7) as u8;
        if cluster.gpu(gpu).unwrap().fits_at(Profile::P1g10gb, anchor) {
            let pl = Placement { gpu, profile: Profile::P1g10gb, index: anchor };
            cluster.allocate(WorkloadId(id), pl).unwrap();
            id += 1;
        }
    }
    let replayed_before = indexed.replayed_events();
    let got = indexed.schedule(&cluster, Profile::P3g40gb);
    assert_eq!(got, evaluate_cluster(indexed.score_table(), cluster.gpus(), Profile::P3g40gb));
    assert!(indexed.replayed_events() > replayed_before, "catch-up must use the change log");
    assert_eq!(indexed.rebuilds(), 1, "no rebuild while the log bridges the gap");

    // (b) A clear() discontinuity cannot be replayed: generation mismatch
    // with an unbridgeable log must force a rebuild, not silent reuse.
    cluster.clear();
    cluster
        .allocate(WorkloadId(id), Placement { gpu: 2, profile: Profile::P4g40gb, index: 0 })
        .unwrap();
    id += 1;
    let got = indexed.schedule(&cluster, Profile::P7g80gb);
    assert_eq!(got, evaluate_cluster(indexed.score_table(), cluster.gpus(), Profile::P7g80gb));
    assert_eq!(indexed.rebuilds(), 2, "discontinuity must trigger a rebuild");

    // (c) Falling further behind than the log capacity also rebuilds.
    for _ in 0..=(CHANGE_LOG_CAPACITY / 2) {
        cluster
            .allocate(WorkloadId(id), Placement { gpu: 0, profile: Profile::P1g10gb, index: 0 })
            .unwrap();
        cluster.release(WorkloadId(id)).unwrap();
        id += 1;
    }
    let got = indexed.schedule(&cluster, Profile::P1g10gb);
    assert_eq!(got, evaluate_cluster(indexed.score_table(), cluster.gpus(), Profile::P1g10gb));
    assert_eq!(indexed.rebuilds(), 3, "log overflow must trigger a rebuild");
}
