//! Error-path coverage for `Cluster::allocate` / `Cluster::release`: the
//! property suite exercises happy paths (every proposed placement is
//! valid); these tests pin the failure modes the serving daemon maps to
//! 4xx responses — double release, overlapping placements, infeasible
//! anchors, unsupported profiles, out-of-range GPUs — and assert that a
//! failed operation never corrupts the accounting.

use migsched::cluster::{AllocError, Cluster};
use migsched::mig::gpu::PlacementError;
use migsched::mig::{HardwareModel, Placement, Profile, ALL_PROFILES};
use migsched::util::check::forall;
use migsched::util::rng::Rng;
use migsched::workload::WorkloadId;

fn cluster(gpus: usize) -> Cluster {
    Cluster::new(HardwareModel::a100_80gb(), gpus)
}

fn pl(gpu: usize, profile: Profile, index: u8) -> Placement {
    Placement { gpu, profile, index }
}

/// Snapshot of the observable accounting, for before/after comparisons.
fn accounting(c: &Cluster) -> (u64, usize, usize, Vec<u8>) {
    (c.used_slices(), c.allocated_workloads(), c.active_gpus(), c.occupancy_masks())
}

#[test]
fn double_release_is_unknown_workload() {
    let mut c = cluster(2);
    c.allocate(WorkloadId(1), pl(0, Profile::P2g20gb, 2)).unwrap();
    c.release(WorkloadId(1)).unwrap();
    let before = accounting(&c);
    assert_eq!(c.release(WorkloadId(1)), Err(AllocError::UnknownWorkload(WorkloadId(1))));
    assert_eq!(accounting(&c), before, "failed release must not mutate state");
    // The slices really are free again.
    assert_eq!(c.used_slices(), 0);
    c.allocate(WorkloadId(2), pl(0, Profile::P2g20gb, 2)).unwrap();
}

#[test]
fn overlapping_placement_rejected_without_corruption() {
    let mut c = cluster(1);
    c.allocate(WorkloadId(1), pl(0, Profile::P4g40gb, 0)).unwrap();
    let before = accounting(&c);
    // Full overlap, partial overlap, and exact-window overlap.
    for bad in [
        pl(0, Profile::P4g40gb, 0),
        pl(0, Profile::P3g40gb, 0),
        pl(0, Profile::P2g20gb, 2),
        pl(0, Profile::P1g10gb, 3),
        pl(0, Profile::P7g80gb, 0),
    ] {
        let err = c.allocate(WorkloadId(99), bad).unwrap_err();
        assert!(
            matches!(err, AllocError::Placement(PlacementError::Occupied { .. })),
            "{bad}: {err}"
        );
        assert_eq!(accounting(&c), before, "{bad}: failed allocate mutated state");
    }
    // Disjoint window still works and the original allocation survives.
    c.allocate(WorkloadId(2), pl(0, Profile::P3g40gb, 4)).unwrap();
    assert_eq!(c.placement_of(WorkloadId(1)), Some(pl(0, Profile::P4g40gb, 0)));
}

#[test]
fn infeasible_anchor_rejected_before_any_mutation() {
    let mut c = cluster(1);
    let before = accounting(&c);
    // Index 1 is not a Table I anchor for 2g.20gb; 4 is not one for 4g.40gb.
    for (profile, index) in
        [(Profile::P2g20gb, 1u8), (Profile::P4g40gb, 4), (Profile::P7g80gb, 1), (Profile::P3g40gb, 2)]
    {
        let err = c.allocate(WorkloadId(7), pl(0, profile, index)).unwrap_err();
        assert_eq!(
            err,
            AllocError::Placement(PlacementError::InfeasibleIndex { profile, start: index }),
        );
    }
    // Out-of-range index is equally an infeasible anchor, not a panic.
    let err = c.allocate(WorkloadId(7), pl(0, Profile::P1g10gb, 7)).unwrap_err();
    assert!(matches!(err, AllocError::Placement(PlacementError::InfeasibleIndex { .. })));
    assert_eq!(accounting(&c), before);
}

#[test]
fn unsupported_profile_rejected_by_policy() {
    // An operator fleet policy disabling full-GPU rentals must reject the
    // profile BEFORE feasibility is consulted.
    let hw = HardwareModel::a100_80gb().with_profiles(&[Profile::P1g10gb, Profile::P2g20gb]);
    let mut c = Cluster::new(hw, 1);
    assert_eq!(
        c.allocate(WorkloadId(0), pl(0, Profile::P7g80gb, 0)),
        Err(AllocError::UnsupportedProfile(Profile::P7g80gb))
    );
    assert_eq!(
        c.allocate(WorkloadId(0), pl(0, Profile::P3g40gb, 0)),
        Err(AllocError::UnsupportedProfile(Profile::P3g40gb))
    );
    assert_eq!(c.used_slices(), 0);
    c.allocate(WorkloadId(0), pl(0, Profile::P2g20gb, 0)).unwrap();
}

#[test]
fn unknown_gpu_and_duplicate_workload() {
    let mut c = cluster(3);
    assert_eq!(
        c.allocate(WorkloadId(0), pl(3, Profile::P1g10gb, 0)),
        Err(AllocError::UnknownGpu { gpu: 3, cluster_size: 3 })
    );
    assert_eq!(
        c.allocate(WorkloadId(0), pl(usize::MAX, Profile::P1g10gb, 0)),
        Err(AllocError::UnknownGpu { gpu: usize::MAX, cluster_size: 3 })
    );
    c.allocate(WorkloadId(0), pl(0, Profile::P1g10gb, 0)).unwrap();
    // Same id again — even on a different, free GPU — is a duplicate.
    assert_eq!(
        c.allocate(WorkloadId(0), pl(1, Profile::P1g10gb, 0)),
        Err(AllocError::DuplicateWorkload(WorkloadId(0)))
    );
    // The first placement is untouched by the failed duplicate.
    assert_eq!(c.placement_of(WorkloadId(0)), Some(pl(0, Profile::P1g10gb, 0)));
    assert_eq!(c.allocated_workloads(), 1);
}

#[test]
fn error_display_is_actionable() {
    let mut c = cluster(1);
    c.allocate(WorkloadId(1), pl(0, Profile::P4g40gb, 0)).unwrap();
    let occupied = c.allocate(WorkloadId(2), pl(0, Profile::P3g40gb, 0)).unwrap_err();
    assert!(occupied.to_string().contains("cannot place"), "{occupied}");
    let unknown = c.release(WorkloadId(9)).unwrap_err();
    assert!(unknown.to_string().contains("not allocated"), "{unknown}");
    let gpu = c.allocate(WorkloadId(3), pl(9, Profile::P1g10gb, 0)).unwrap_err();
    assert!(gpu.to_string().contains("out of range"), "{gpu}");
}

#[test]
fn prop_invalid_operations_never_corrupt_accounting() {
    // Interleave valid operations with systematically injected invalid
    // ones; after every step the incremental accounting must equal the
    // ground truth recomputed from the occupancy masks, and every invalid
    // operation must (a) error and (b) leave the state byte-identical.
    forall(
        "cluster-error-paths",
        |rng| (rng.next_u64(), 2 + rng.index(4), 40 + rng.index(80)),
        |&(seed, gpus, steps)| {
            let hw = HardwareModel::a100_80gb();
            let mut rng = Rng::new(seed);
            let mut c = Cluster::new(hw, gpus);
            let mut next_id = 0u64;
            for _ in 0..steps {
                match rng.index(5) {
                    // Valid allocate at a random feasible spot.
                    0 | 1 => {
                        let p = *rng.choose(&ALL_PROFILES);
                        let gpu = rng.index(c.num_gpus());
                        let feasible: Vec<u8> =
                            c.gpu(gpu).unwrap().feasible_indexes(p).collect();
                        if let Some(&idx) = feasible.first() {
                            c.allocate(WorkloadId(next_id), pl(gpu, p, idx))
                                .map_err(|e| format!("valid allocate failed: {e}"))?;
                            next_id += 1;
                        }
                    }
                    // Valid release.
                    2 => {
                        if c.allocated_workloads() > 0 {
                            let ids: Vec<WorkloadId> =
                                c.allocations().map(|(id, _)| id).collect();
                            c.release(*rng.choose(&ids)).map_err(|e| e.to_string())?;
                        }
                    }
                    // Injected invalid allocate (occupied window / bad gpu
                    // / bad anchor) — must error, must not mutate.
                    3 => {
                        let before = accounting(&c);
                        let p = *rng.choose(&ALL_PROFILES);
                        let bad = match rng.index(3) {
                            0 => pl(c.num_gpus() + rng.index(3), p, p.starts()[0]),
                            1 => pl(rng.index(c.num_gpus()), p, 7),
                            _ => {
                                // Aim at an occupied window when one exists.
                                match c.allocations().next() {
                                    Some((_, taken)) => {
                                        pl(taken.gpu, taken.profile, taken.index)
                                    }
                                    None => pl(c.num_gpus(), p, p.starts()[0]),
                                }
                            }
                        };
                        if c.allocate(WorkloadId(next_id), bad).is_ok() {
                            return Err(format!("invalid allocate {bad} was accepted"));
                        }
                        if accounting(&c) != before {
                            return Err(format!("failed allocate {bad} mutated state"));
                        }
                    }
                    // Injected invalid release — must error, must not mutate.
                    _ => {
                        let before = accounting(&c);
                        if c.release(WorkloadId(next_id + 1_000_000)).is_ok() {
                            return Err("release of unknown workload succeeded".into());
                        }
                        if accounting(&c) != before {
                            return Err("failed release mutated state".into());
                        }
                    }
                }
                // Ground truth: per-GPU masks vs incremental counters.
                let mask_slices: u64 =
                    c.gpus().iter().map(|g| g.used_slices() as u64).sum();
                if c.used_slices() != mask_slices {
                    return Err(format!(
                        "incremental used_slices {} != mask ground truth {mask_slices}",
                        c.used_slices()
                    ));
                }
            }
            Ok(())
        },
    );
}
