//! CLI smoke tests: run the built `migsched` binary end-to-end for every
//! offline subcommand and assert on its output and exit codes.

use std::process::Command;

fn migsched(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_migsched"))
        .args(args)
        .output()
        .expect("spawn migsched");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = migsched(&["help"]);
    assert!(ok);
    for cmd in ["sim", "sweep", "figures", "serve", "inspect", "trace-record"] {
        assert!(stdout.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (_, stderr, ok) = migsched(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn sim_small_run() {
    let (stdout, _, ok) = migsched(&[
        "sim", "--gpus", "8", "--seed", "7", "--scheduler", "MFI",
        "--distribution", "skew-small",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("scheme=MFI"));
    assert!(stdout.contains("distribution=skew-small"));
    assert!(stdout.contains("100%"));
    assert!(stdout.contains("whole-run acceptance"));
}

#[test]
fn sim_rejects_bad_flags() {
    let (_, stderr, ok) = migsched(&["sim", "--scheduler", "SLURM"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scheduler"));
    let (_, stderr, ok) = migsched(&["sim", "--gpus", "not-a-number"]);
    assert!(!ok);
    assert!(stderr.contains("integer"));
}

#[test]
fn inspect_outputs() {
    let (stdout, _, ok) = migsched(&["inspect", "--hardware", "a100-80gb"]);
    assert!(ok);
    assert!(stdout.contains("7g.80gb"));
    let (stdout, _, ok) = migsched(&["inspect", "--distributions"]);
    assert!(ok);
    assert!(stdout.contains("skew-small"));
    let (stdout, _, ok) = migsched(&["inspect", "--candidates"]);
    assert!(ok);
    assert!(stdout.contains("\"mask\""));
    let (_, stderr, ok) = migsched(&["inspect"]);
    assert!(!ok);
    assert!(stderr.contains("inspect needs"));
}

#[test]
fn trace_record_and_replay_roundtrip() {
    let dir = std::env::temp_dir().join(format!("migsched-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("t.jsonl");
    let (stdout, _, ok) = migsched(&[
        "trace-record", "--out", trace.to_str().unwrap(), "--gpus", "8", "--seed", "3",
        "--distribution", "bimodal",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("wrote"));
    let (stdout, _, ok) = migsched(&[
        "trace-replay", "--trace", trace.to_str().unwrap(), "--scheduler", "BF-BI",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("\"scheme\": \"BF-BI\""));
    assert!(stdout.contains("acceptance_rate"));
    std::fs::remove_dir_all(&dir).unwrap();
}

fn sample(name: &str) -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/traces")
        .join(name)
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn trace_ingest_stats_replay_workflow() {
    let dir = std::env::temp_dir().join(format!("migsched-cli-trace-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("ali.jsonl");
    let report = dir.join("report.json");

    // Ingest the bundled Alibaba-style sample.
    let (stdout, stderr, ok) = migsched(&[
        "trace", "ingest", "--format", "alibaba", "--in", &sample("sample_alibaba.csv"),
        "--out", out.to_str().unwrap(), "--gpus", "4",
        "--report", report.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("ingest report"));
    assert!(stdout.contains("wrote"));
    assert!(report.exists());

    // Stats over the ingested trace.
    let (stdout, _, ok) = migsched(&["trace", "stats", "--trace", out.to_str().unwrap()]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("1g.10gb"));
    assert!(stdout.contains("inter-arrival"));
    assert!(stdout.contains("lifespan"));

    // Stats straight off the CSV (on-the-fly ingest) agree on arrivals.
    let (stdout2, _, ok) = migsched(&[
        "trace", "stats", "--format", "alibaba", "--in", &sample("sample_alibaba.csv"),
        "--gpus", "4", "--json",
    ]);
    assert!(ok, "{stdout2}");
    assert!(stdout2.contains("\"arrivals\""));

    // Replay through MFI and MFI-IDX: identical acceptance (the index
    // equivalence acceptance criterion, exercised at the CLI surface).
    let accepted_of = |sched: &str| -> u64 {
        let (stdout, stderr, ok) = migsched(&[
            "trace", "replay", "--trace", out.to_str().unwrap(), "--sched", sched,
            "--gpus", "2", "--json",
        ]);
        assert!(ok, "{sched}: {stdout}\n{stderr}");
        let line = stdout
            .lines()
            .find(|l| l.trim_start().starts_with("\"accepted\""))
            .unwrap_or_else(|| panic!("{sched}: no accepted field in {stdout}"));
        line.trim()
            .trim_start_matches("\"accepted\":")
            .trim()
            .trim_end_matches(',')
            .parse()
            .unwrap()
    };
    let mfi = accepted_of("mfi");
    let mfi_idx = accepted_of("mfi-idx");
    assert_eq!(mfi, mfi_idx, "MFI vs MFI-IDX acceptance must match");
    assert!(mfi > 0);

    // Philly sample straight through replay (ingest-on-the-fly).
    let (stdout, stderr, ok) = migsched(&[
        "trace", "replay", "--format", "philly", "--in", &sample("sample_philly.csv"),
        "--sched", "mfi", "--gpus", "2", "--max-events", "20",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("\"conserved\": true"), "{stdout}");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn trace_replay_defrag_flags_recover_acceptance() {
    use migsched::mig::Profile;
    use migsched::workload::{TenantId, Trace, Workload, WorkloadId};
    let dir = std::env::temp_dir().join(format!("migsched-cli-defrag-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("frag.jsonl");

    // The deterministic consolidation scenario from the replay unit
    // tests: under FF on 2 GPUs the slot-3 departures strand a 2g + a
    // 1g.20gb on one GPU and a 2g on the other, so the 7g.80gb arriving
    // at slot 10 is rejected — unless a defrag sweep consolidates first.
    let w = |id: u64, profile, arrival: u64, dur: u64| Workload {
        id: WorkloadId(id),
        tenant: TenantId(0),
        profile,
        arrival_slot: arrival,
        duration_slots: dur,
    };
    let trace = Trace::from_workloads(
        "cli defrag",
        64,
        &[
            w(0, Profile::P2g20gb, 0, 3),
            w(1, Profile::P2g20gb, 0, 100),
            w(2, Profile::P2g20gb, 0, 3),
            w(3, Profile::P1g20gb, 0, 100),
            w(4, Profile::P2g20gb, 0, 100),
            w(5, Profile::P2g20gb, 0, 3),
            w(6, Profile::P7g80gb, 10, 5),
        ],
    );
    trace.save(&path).unwrap();
    let field = |stdout: &str, key: &str| -> u64 {
        let pat = format!("\"{key}\"");
        let line = stdout
            .lines()
            .find(|l| l.trim_start().starts_with(&pat))
            .unwrap_or_else(|| panic!("no {key} field in {stdout}"));
        line.trim()
            .trim_start_matches(&pat)
            .trim_start_matches(':')
            .trim()
            .trim_end_matches(',')
            .parse()
            .unwrap()
    };

    // Baseline: no defrag flags → the full-GPU request is lost and the
    // output carries no migration keys (byte-stable legacy shape).
    let (stdout, stderr, ok) = migsched(&[
        "trace", "replay", "--trace", path.to_str().unwrap(), "--sched", "ff",
        "--gpus", "2", "--json",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert_eq!(field(&stdout, "accepted"), 6);
    assert!(!stdout.contains("\"migrations\""), "{stdout}");

    // With the sweep enabled the 7g fits and the migrations are reported.
    let (stdout, stderr, ok) = migsched(&[
        "trace", "replay", "--trace", path.to_str().unwrap(), "--sched", "ff",
        "--gpus", "2", "--defrag-every", "5", "--json",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert_eq!(field(&stdout, "accepted"), 7);
    assert_eq!(field(&stdout, "migrations"), 1);
    assert!(field(&stdout, "migrated_bytes") > 0);
    assert!(stdout.contains("\"conserved\": true"), "{stdout}");

    // Refinement knobs without --defrag-every are an error, not a no-op.
    let (_, stderr, ok) = migsched(&[
        "trace", "replay", "--trace", path.to_str().unwrap(), "--defrag-budget", "40",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--defrag-budget requires --defrag-every"), "{stderr}");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sim_defrag_flags_report_migrations() {
    let (stdout, stderr, ok) = migsched(&[
        "sim", "--gpus", "8", "--seed", "7", "--scheduler", "FF",
        "--defrag-every", "10",
    ]);
    assert!(ok, "{stdout}\n{stderr}");
    assert!(stdout.contains("defrag: migrations="), "{stdout}");
    // Without the flag the line stays out of the report.
    let (stdout, _, ok) = migsched(&["sim", "--gpus", "8", "--seed", "7", "--scheduler", "FF"]);
    assert!(ok);
    assert!(!stdout.contains("defrag:"), "{stdout}");
}

#[test]
fn trace_subcommand_errors_are_friendly() {
    let (_, stderr, ok) = migsched(&["trace"]);
    assert!(!ok);
    assert!(stderr.contains("subcommand"));
    let (_, stderr, ok) = migsched(&["trace", "ingest", "--in", "/nonexistent.csv"]);
    assert!(!ok);
    assert!(stderr.contains("--format") || stderr.contains("--out"));
    let (_, stderr, ok) = migsched(&[
        "trace", "ingest", "--format", "borg", "--in", "x.csv", "--out", "y.jsonl",
    ]);
    assert!(!ok);
    assert!(stderr.contains("unknown trace format"));
    let (_, stderr, ok) = migsched(&["trace", "stats"]);
    assert!(!ok);
    assert!(stderr.contains("--trace") || stderr.contains("--in"));
    // Ingest knobs on an existing --trace are rejected, not ignored.
    let (_, stderr, ok) = migsched(&[
        "trace", "replay", "--trace", "t.jsonl", "--slot-secs", "60",
    ]);
    assert!(!ok);
    assert!(stderr.contains("no effect on an existing --trace"), "{stderr}");
    // --gpus 0 is a friendly error, not an assert panic.
    let (_, stderr, ok) = migsched(&[
        "trace", "ingest", "--format", "alibaba", "--in", "x.csv", "--out", "y.jsonl",
        "--gpus", "0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--gpus must be positive"), "{stderr}");
}

#[test]
fn figures_quick() {
    let dir = std::env::temp_dir().join(format!("migsched-cli-fig-{}", std::process::id()));
    let (stdout, _, ok) = migsched(&[
        "figures", "--fig", "6", "--runs", "3", "--gpus", "8",
        "--schemes", "MFI,FF", "--out", dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Fig. 6"));
    assert!(dir.join("fig6_fragmentation_score.csv").exists());
    std::fs::remove_dir_all(&dir).unwrap();
    let (_, stderr, ok) = migsched(&["figures", "--fig", "9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"));
}
