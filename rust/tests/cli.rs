//! CLI smoke tests: run the built `migsched` binary end-to-end for every
//! offline subcommand and assert on its output and exit codes.

use std::process::Command;

fn migsched(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_migsched"))
        .args(args)
        .output()
        .expect("spawn migsched");
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
        out.status.success(),
    )
}

#[test]
fn help_lists_commands() {
    let (stdout, _, ok) = migsched(&["help"]);
    assert!(ok);
    for cmd in ["sim", "sweep", "figures", "serve", "inspect", "trace-record"] {
        assert!(stdout.contains(cmd), "help missing '{cmd}'");
    }
}

#[test]
fn unknown_command_fails_with_usage() {
    let (_, stderr, ok) = migsched(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn sim_small_run() {
    let (stdout, _, ok) = migsched(&[
        "sim", "--gpus", "8", "--seed", "7", "--scheduler", "MFI",
        "--distribution", "skew-small",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("scheme=MFI"));
    assert!(stdout.contains("distribution=skew-small"));
    assert!(stdout.contains("100%"));
    assert!(stdout.contains("whole-run acceptance"));
}

#[test]
fn sim_rejects_bad_flags() {
    let (_, stderr, ok) = migsched(&["sim", "--scheduler", "SLURM"]);
    assert!(!ok);
    assert!(stderr.contains("unknown scheduler"));
    let (_, stderr, ok) = migsched(&["sim", "--gpus", "not-a-number"]);
    assert!(!ok);
    assert!(stderr.contains("integer"));
}

#[test]
fn inspect_outputs() {
    let (stdout, _, ok) = migsched(&["inspect", "--hardware", "a100-80gb"]);
    assert!(ok);
    assert!(stdout.contains("7g.80gb"));
    let (stdout, _, ok) = migsched(&["inspect", "--distributions"]);
    assert!(ok);
    assert!(stdout.contains("skew-small"));
    let (stdout, _, ok) = migsched(&["inspect", "--candidates"]);
    assert!(ok);
    assert!(stdout.contains("\"mask\""));
    let (_, stderr, ok) = migsched(&["inspect"]);
    assert!(!ok);
    assert!(stderr.contains("inspect needs"));
}

#[test]
fn trace_record_and_replay_roundtrip() {
    let dir = std::env::temp_dir().join(format!("migsched-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("t.jsonl");
    let (stdout, _, ok) = migsched(&[
        "trace-record", "--out", trace.to_str().unwrap(), "--gpus", "8", "--seed", "3",
        "--distribution", "bimodal",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("wrote"));
    let (stdout, _, ok) = migsched(&[
        "trace-replay", "--trace", trace.to_str().unwrap(), "--scheduler", "BF-BI",
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("\"scheme\": \"BF-BI\""));
    assert!(stdout.contains("acceptance_rate"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn figures_quick() {
    let dir = std::env::temp_dir().join(format!("migsched-cli-fig-{}", std::process::id()));
    let (stdout, _, ok) = migsched(&[
        "figures", "--fig", "6", "--runs", "3", "--gpus", "8",
        "--schemes", "MFI,FF", "--out", dir.to_str().unwrap(),
    ]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("Fig. 6"));
    assert!(dir.join("fig6_fragmentation_score.csv").exists());
    std::fs::remove_dir_all(&dir).unwrap();
    let (_, stderr, ok) = migsched(&["figures", "--fig", "9"]);
    assert!(!ok);
    assert!(stderr.contains("unknown figure"));
}
