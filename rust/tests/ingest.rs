//! Trace-ingestion integration tests: edge cases over the full
//! CSV → mapper → normalize → Trace → replay pipeline, plus the bundled
//! sample traces under `examples/traces/`.
//!
//! The two repo-level invariants pinned here:
//! * ingesting either bundled sample round-trips through the JSON-lines
//!   trace format **byte-identically**;
//! * MFI and MFI-IDX produce **identical acceptance counts** replaying
//!   the bundled samples open-loop (index equivalence beyond the
//!   saturation protocol).

use std::path::PathBuf;

use migsched::sim::replay::{self, ReplayConfig};
use migsched::sched::SchedulerKind;
use migsched::mig::HardwareModel;
use migsched::workload::ingest::{
    ingest_path, ingest_str, IngestConfig, MappingPolicy, TraceFormat,
};
use migsched::workload::Trace;

fn sample(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/traces").join(name)
}

const ALI_HEADER: &str =
    "job_name,task_name,inst_num,status,start_time,end_time,plan_cpu,plan_mem,plan_gpu,gpu_type";

fn ali_config() -> IngestConfig {
    IngestConfig::new(TraceFormat::Alibaba).with_gpus(8)
}

// ---------- edge cases: never panic, always account ----------------------

#[test]
fn malformed_and_truncated_rows_are_counted_not_fatal() {
    let text = format!(
        "{ALI_HEADER}\n\
         job_a,tf,1,Terminated,0,600,1,10,50,V100\n\
         job_b,tf,1,Terminated,60\n\
         \"job_c,tf,1,Terminated,120,720,1,10,50,V100\n\
         job_d,tf,one,Terminated,180,780,1,10,50,V100\n\
         job_e,tf,1,Terminated,240,840,1,10,50,V100"
    );
    // NOTE: job_b is truncated mid-row, job_c has an unterminated quote,
    // job_d a non-numeric inst_num, and the file lacks a final newline.
    let (trace, report) = ingest_str(&text, "edge", &ali_config()).unwrap();
    assert_eq!(report.rows_total, 5);
    assert_eq!(report.imported, 2);
    assert_eq!(report.skipped_malformed, 3);
    assert_eq!(report.errors.len(), 3);
    assert_eq!(trace.arrivals().len(), 2);
}

#[test]
fn stray_non_utf8_bytes_cost_one_row_not_the_file() {
    use migsched::workload::ingest::ingest_reader;
    let mut bytes = format!(
        "{ALI_HEADER}\n\
         good1,tf,1,Terminated,0,600,1,10,50,V100\n"
    )
    .into_bytes();
    // A row whose plan_gpu field contains a raw 0xFF byte: lossy decoding
    // turns it into U+FFFD, the number parse fails, the row is skipped.
    bytes.extend_from_slice(b"bad,tf,1,Terminated,60,660,1,10,5\xFF0,V100\n");
    bytes.extend_from_slice(b"good2,tf,1,Terminated,120,720,1,10,50,V100\n");
    let (trace, report) =
        ingest_reader(&bytes[..], "binary", &ali_config()).unwrap();
    assert_eq!(report.rows_total, 3);
    assert_eq!(report.imported, 2);
    assert_eq!(report.skipped_malformed, 1);
    assert_eq!(trace.arrivals().len(), 2);
}

#[test]
fn newline_free_blob_costs_one_row_not_the_process() {
    use migsched::workload::ingest::{ingest_reader, MAX_LINE_BYTES};
    // A >1 MiB junk line with no newline between two valid rows: it must
    // become one skipped row (its tail discarded, not buffered), and the
    // following row must still import.
    let mut bytes = format!(
        "{ALI_HEADER}\n\
         good1,tf,1,Terminated,0,600,1,10,50,V100\n"
    )
    .into_bytes();
    bytes.extend(std::iter::repeat(b'x').take(MAX_LINE_BYTES + 4096));
    bytes.push(b'\n');
    bytes.extend_from_slice(b"good2,tf,1,Terminated,120,720,1,10,50,V100\n");
    let (trace, report) = ingest_reader(&bytes[..], "blob", &ali_config()).unwrap();
    assert_eq!(report.rows_total, 3);
    assert_eq!(report.imported, 2);
    assert_eq!(report.skipped_malformed, 1);
    assert!(report.errors[0].reason.contains("exceeds"));
    assert_eq!(trace.arrivals().len(), 2);

    // A newline-free junk FILE fails on the header, without buffering it.
    let blob: Vec<u8> =
        std::iter::repeat(b'z').take(MAX_LINE_BYTES + 4096).collect();
    assert!(ingest_reader(&blob[..], "pure-blob", &ali_config()).is_err());
}

#[test]
fn cpu_only_rows_are_filtered_not_errors() {
    // Empty and zero plan_gpu (CPU tasks, a large share of the real
    // Alibaba dump) land in their own filter counter, keeping the error
    // detail and ok_fraction meaningful.
    let text = format!(
        "{ALI_HEADER}\n\
         cpu1,tf,1,Terminated,0,600,600,10,,V100\n\
         cpu2,tf,1,Terminated,0,600,600,10,0,V100\n\
         gpu1,tf,1,Terminated,0,600,600,10,50,V100\n"
    );
    let (trace, report) = ingest_str(&text, "cpu", &ali_config()).unwrap();
    assert_eq!(report.filtered_no_gpu, 2);
    assert_eq!(report.skipped_malformed, 0);
    assert!(report.errors.is_empty());
    assert_eq!(report.imported, 1);
    assert_eq!(report.ok_fraction(), 1.0);
    assert_eq!(trace.arrivals().len(), 1);
}

#[test]
fn zero_duration_jobs_occupy_one_slot() {
    let text = format!(
        "{ALI_HEADER}\n\
         j,tf,1,Terminated,500,500,1,10,50,V100\n"
    );
    let (trace, report) = ingest_str(&text, "zero", &ali_config()).unwrap();
    assert_eq!(report.zero_duration, 1);
    let arrivals = trace.arrivals();
    assert_eq!(arrivals.len(), 1);
    assert_eq!(arrivals[0].duration_slots, 1);
}

#[test]
fn out_of_order_timestamps_normalize_to_a_sorted_trace() {
    let text = format!(
        "{ALI_HEADER}\n\
         late,tf,1,Terminated,100000,100600,1,10,50,V100\n\
         early,tf,1,Terminated,0,600,1,10,50,V100\n\
         mid,tf,1,Terminated,50000,50600,1,10,50,V100\n"
    );
    let (trace, _) = ingest_str(&text, "ooo", &ali_config()).unwrap();
    let arrivals = trace.arrivals();
    assert_eq!(arrivals.len(), 3);
    assert!(arrivals.windows(2).all(|w| w[0].arrival_slot <= w[1].arrival_slot));
    assert_eq!(arrivals[0].arrival_slot, 0); // "early" anchors the clock
    // Ids are canonical (assigned post-sort), so replays are
    // deterministic regardless of source row order.
    assert!(arrivals.windows(2).all(|w| w[0].id < w[1].id));
}

#[test]
fn unmappable_share_under_strict_policy_is_a_skip_count() {
    let text = format!(
        "{ALI_HEADER}\n\
         multi,tf,1,Terminated,0,600,1,10,800,V100\n\
         fits,tf,1,Terminated,0,600,1,10,100,V100\n"
    );
    let cfg = ali_config().with_policy(MappingPolicy::Strict);
    let (trace, report) = ingest_str(&text, "strict", &cfg).unwrap();
    assert_eq!(report.unmappable, 1);
    assert_eq!(report.imported, 1);
    assert!(!report.errors.is_empty());
    assert_eq!(trace.arrivals().len(), 1);
    assert!(report.ok_fraction() < 1.0);
}

#[test]
fn empty_and_header_only_files_ingest_cleanly() {
    let (trace, report) = ingest_str("", "empty", &ali_config()).unwrap();
    assert_eq!((report.rows_total, trace.arrivals().len()), (0, 0));
    let (trace, report) =
        ingest_str(&format!("{ALI_HEADER}\n"), "header-only", &ali_config()).unwrap();
    assert_eq!((report.rows_total, trace.arrivals().len()), (0, 0));
    // Blank lines anywhere are skipped, not rows.
    let (_, report) = ingest_str(
        &format!("\n\n{ALI_HEADER}\n\nj,tf,1,Terminated,0,9,1,1,25,V\n\n"),
        "blanky",
        &ali_config(),
    )
    .unwrap();
    assert_eq!(report.rows_total, 1);
    assert_eq!(report.imported, 1);
    // And an empty trace replays to an empty result.
    let (trace, _) = ingest_str("", "empty", &ali_config()).unwrap();
    let mut sched = SchedulerKind::Mfi.build(&HardwareModel::a100_80gb());
    let r = replay::run(&trace, &mut *sched, &ReplayConfig::new(4));
    assert_eq!(r.arrived, 0);
    assert!(r.conserved());
}

// ---------- bundled samples: the repo-level acceptance invariants --------

#[test]
fn bundled_samples_ingest_with_zero_malformed_rows() {
    for (name, format) in [
        ("sample_alibaba.csv", TraceFormat::Alibaba),
        ("sample_philly.csv", TraceFormat::Philly),
    ] {
        let cfg = IngestConfig::new(format).with_gpus(8);
        let (trace, report) = ingest_path(&sample(name), &cfg).unwrap();
        assert_eq!(report.skipped_malformed, 0, "{name}: {:?}", report.errors);
        assert_eq!(report.unmappable, 0, "{name}");
        assert!(report.imported > 0, "{name}");
        assert_eq!(trace.arrivals().len() as u64, report.imported, "{name}");
        // Stats over the ingested trace are well-formed.
        let stats = trace.stats();
        assert_eq!(stats.arrivals, report.imported, "{name}");
        assert!(stats.lifespan_slots.p50 >= 1.0, "{name}");
    }
}

#[test]
fn bundled_samples_roundtrip_jsonl_byte_identically() {
    for (name, format) in [
        ("sample_alibaba.csv", TraceFormat::Alibaba),
        ("sample_philly.csv", TraceFormat::Philly),
    ] {
        let cfg = IngestConfig::new(format).with_gpus(8);
        let (trace, _) = ingest_path(&sample(name), &cfg).unwrap();
        let rendered = trace.render_jsonl();
        let reparsed = Trace::parse_jsonl(&rendered).unwrap();
        assert_eq!(reparsed.render_jsonl(), rendered, "{name}");
        assert_eq!(reparsed, trace, "{name}");
    }
}

#[test]
fn mfi_and_indexed_mfi_accept_identically_on_bundled_samples() {
    for (name, format, gpus) in [
        ("sample_alibaba.csv", TraceFormat::Alibaba, 2),
        ("sample_philly.csv", TraceFormat::Philly, 2),
        ("bench_alibaba_2k.csv", TraceFormat::Alibaba, 6),
    ] {
        let cfg = IngestConfig::new(format).with_gpus(gpus);
        let (trace, _) = ingest_path(&sample(name), &cfg).unwrap();
        let hw = HardwareModel::a100_80gb();
        let rcfg = ReplayConfig::new(gpus);
        let mut flat = SchedulerKind::Mfi.build(&hw);
        let mut indexed = SchedulerKind::MfiIdx.build(&hw);
        let a = replay::run(&trace, &mut *flat, &rcfg);
        let b = replay::run(&trace, &mut *indexed, &rcfg);
        assert_eq!(a.accepted, b.accepted, "{name}");
        assert_eq!(a.rejected, b.rejected, "{name}");
        assert_eq!(a.time_avg_frag, b.time_avg_frag, "{name}");
        assert!(a.conserved() && b.conserved(), "{name}");
        // Small clusters must actually exercise rejection for the
        // equivalence to mean anything.
        assert!(a.rejected > 0, "{name}: no rejections at M={gpus}");
    }
}

#[test]
fn every_scheduler_conserves_counters_on_the_bench_trace_prefix() {
    let cfg = IngestConfig::new(TraceFormat::Alibaba).with_gpus(4);
    let (trace, _) = ingest_path(&sample("bench_alibaba_2k.csv"), &cfg).unwrap();
    let hw = HardwareModel::a100_80gb();
    let rcfg = ReplayConfig { max_events: 500, ..ReplayConfig::new(4) };
    for kind in SchedulerKind::all() {
        let mut sched = kind.build(&hw);
        let r = replay::run(&trace, &mut *sched, &rcfg);
        assert_eq!(r.arrived, 500, "{kind}");
        assert!(r.conserved(), "{kind}");
        assert!(r.accepted > 0, "{kind}");
    }
}
