//! Property test: `POST /v1/submit/batch` is **bit-identical** to issuing
//! the same submits sequentially through `POST /v1/workloads`.
//!
//! Two shard sets are built from the same configuration and driven with
//! the same randomized operation stream — one receives each round's
//! submits as a single batch, the other as N sequential requests, with
//! ticks and releases interleaved identically. After every round the
//! per-item batch results must equal the sequential response bodies byte
//! for byte, and at the end `/v1/stats`, `/v1/cluster` and the
//! deterministic `/metrics` families must agree exactly. This pins the
//! batch endpoint's amortized one-lock-per-shard walk to the same
//! placements, counters and tie-breaking as the plain path across shard
//! counts 1, 4 and 16.

use std::collections::HashMap;
use std::sync::Arc;

use migsched::prelude::*;
use migsched::server::api::dispatch;
use migsched::server::{Daemon, DaemonConfig, Request, Response, ShardSet};
use migsched::util::json::Json;

const PROFILES: &[&str] = &["1g.10gb", "2g.20gb", "3g.40gb", "4g.40gb", "7g.80gb"];

fn shard_set(shards: usize) -> Arc<ShardSet> {
    Daemon::new(DaemonConfig {
        num_gpus: 32,
        shards,
        workers: 1,
        scheduler: SchedulerKind::MfiIdx,
        ..DaemonConfig::default()
    })
    .shards()
}

fn req(method: &str, path: &str, body: String) -> Request {
    Request {
        method: method.into(),
        path: path.into(),
        query: HashMap::new(),
        headers: Vec::new(),
        body: body.into_bytes(),
        keep_alive: false,
    }
}

fn body_str(r: &Response) -> String {
    String::from_utf8(r.body.to_vec()).expect("utf-8 response body")
}

/// One random submit request. Occasionally malformed (missing or unknown
/// profile) so error bodies are pinned through the batch path too.
fn random_submit(rng: &mut Rng) -> Json {
    if rng.chance(0.04) {
        return Json::obj().with("tenant", rng.below(50));
    }
    if rng.chance(0.04) {
        return Json::obj().with("profile", "9g.90gb");
    }
    let mut item = Json::obj().with("profile", *rng.choose(PROFILES));
    if rng.chance(0.8) {
        item.set("tenant", rng.below(50));
    }
    if rng.chance(0.5) {
        item.set("duration_slots", rng.range_inclusive(1, 20));
    }
    item
}

/// The `/metrics` lines that must match exactly between the two sets:
/// everything except uptime and the wall-clock-valued decision-latency
/// lines (their `_count` IS deterministic and stays in).
fn deterministic_metrics(text: &str) -> String {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter(|l| !l.starts_with("migsched_uptime_seconds"))
        .filter(|l| {
            !l.starts_with("migsched_sched_decision_seconds")
                || l.starts_with("migsched_sched_decision_seconds_count")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Drive one randomized episode on a given shard count and seed.
fn run_case(shards: usize, seed: u64) {
    let batched = shard_set(shards);
    let sequential = shard_set(shards);
    let mut rng = Rng::new(seed);
    let mut live_ids: Vec<u64> = Vec::new();

    for round in 0..10 {
        let items: Vec<Json> =
            (0..rng.range_inclusive(1, 8)).map(|_| random_submit(&mut rng)).collect();

        // Batch path on set A.
        let batch_body = Json::obj().with("requests", Json::Arr(items.clone())).to_string_compact();
        let br = dispatch(&req("POST", "/v1/submit/batch", batch_body), &batched);
        assert_eq!(br.status, 200, "case shards={shards} seed={seed}: {}", body_str(&br));
        let envelope = Json::parse(&body_str(&br)).expect("batch envelope JSON");
        let results = envelope.get("results").and_then(Json::as_arr).expect("results array");
        assert_eq!(results.len(), items.len());

        // Sequential path on set B, comparing item by item.
        let mut accepted = 0u64;
        for (i, item) in items.iter().enumerate() {
            let sr = dispatch(&req("POST", "/v1/workloads", item.to_string_compact()), &sequential);
            if sr.status == 201 {
                accepted += 1;
                let id = Json::parse(&body_str(&sr)).unwrap().req_u64("id").unwrap();
                live_ids.push(id);
            }
            assert_eq!(
                results[i].to_string_compact(),
                body_str(&sr),
                "shards={shards} seed={seed} round={round} item={i}: batch result \
                 diverged from the sequential response for {}",
                item.to_string_compact()
            );
        }
        assert_eq!(
            envelope.req_u64("accepted").unwrap(),
            accepted,
            "shards={shards} seed={seed} round={round}: accepted count"
        );
        assert_eq!(
            envelope.req_u64("rejected").unwrap(),
            items.len() as u64 - accepted,
            "shards={shards} seed={seed} round={round}: rejected count"
        );

        // Interleave identical releases and clock ticks on both sets.
        if !live_ids.is_empty() && rng.chance(0.5) {
            let id = live_ids.swap_remove(rng.index(live_ids.len()));
            let path = format!("/v1/workloads/{id}");
            let ra = dispatch(&req("DELETE", &path, String::new()), &batched);
            let rb = dispatch(&req("DELETE", &path, String::new()), &sequential);
            assert_eq!(ra.status, rb.status, "release status for id {id}");
            assert_eq!(body_str(&ra), body_str(&rb), "release body for id {id}");
        }
        if rng.chance(0.4) {
            let body = Json::obj().with("slots", rng.range_inclusive(1, 5)).to_string_compact();
            let ra = dispatch(&req("POST", "/v1/tick", body.clone()), &batched);
            let rb = dispatch(&req("POST", "/v1/tick", body), &sequential);
            assert_eq!(body_str(&ra), body_str(&rb), "tick body");
        }
    }

    // Whole-cluster state must agree, not just per-response bodies.
    for path in ["/v1/stats", "/v1/cluster"] {
        let ra = dispatch(&req("GET", path, String::new()), &batched);
        let rb = dispatch(&req("GET", path, String::new()), &sequential);
        assert_eq!(
            body_str(&ra),
            body_str(&rb),
            "shards={shards} seed={seed}: {path} diverged"
        );
    }
    let ma = dispatch(&req("GET", "/metrics", String::new()), &batched);
    let mb = dispatch(&req("GET", "/metrics", String::new()), &sequential);
    assert_eq!(
        deterministic_metrics(&body_str(&ma)),
        deterministic_metrics(&body_str(&mb)),
        "shards={shards} seed={seed}: deterministic metrics families diverged"
    );
}

#[test]
fn batch_equals_sequential_across_shard_counts() {
    for &shards in &[1usize, 4, 16] {
        for seed in 0..12u64 {
            run_case(shards, seed);
        }
    }
}
