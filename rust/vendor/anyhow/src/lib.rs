//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The migsched build environment has no crates.io access, so this vendored
//! crate provides exactly the surface the workspace uses:
//!
//! * [`Error`] — a context-chained error value (no backtraces);
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros;
//! * `From<E: std::error::Error + Send + Sync + 'static>` so `?` converts
//!   any standard error.
//!
//! Formatting mirrors upstream: `{}` prints the outermost message, `{:#}`
//! prints the whole chain joined by `": "`, and `{:?}` prints the chain as
//! a `Caused by:` list.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. Outermost message first.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) message;
    /// the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (the `anyhow!` macro body).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    fn push_context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error with an outer message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated outer message.
    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).push_context(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).push_context(context()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.push_context(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.push_context(context()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, context: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
    }

    #[test]
    fn context_chains_and_formats() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("file missing"));
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: std::result::Result<u32, std::io::Error> = Ok(7);
        let v = r
            .with_context(|| -> String { panic!("must not evaluate") })
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing field").unwrap_err().to_string(), "missing field");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky 7");
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
        assert_eq!(e.chain().count(), 2);
    }
}
